//! Beyond-the-paper experiment: package-level (NoP) congestion.
//!
//! The analytical package model (bandwidth bound + fixed SerDes latency) is
//! load-independent, so it cannot see queueing on the interposer — exactly
//! where scale-out studies report analytical models diverging from flit
//! simulation at k ≥ 16 chiplets. This experiment quantifies both sides:
//!
//! 1. **Uniform steady sweep** — for k ∈ {4, 8, 16, 25} and each package
//!    topology, the low-load average latency of the flit-level simulator
//!    against the analytical prediction (they must agree within ~15%), and
//!    the uniform injection rate at which the package saturates (where they
//!    cannot agree — the analytical column would never move).
//! 2. **DNN-driven drain** — one frame of a real model's inter-chiplet
//!    traffic (the [`ChipletPartition`] injection matrix lowered to NoP
//!    flows) drained through the simulator per topology.
//!
//! The (k × topology) points fan out over OS threads via the coordinator's
//! [`par_map`] — the same driver primitive the evaluation sweeps use.
//!
//! Under `--surrogate` ([`Options::nop_mode`]) both parts answer from the
//! sim-anchored curves of [`crate::sim::surrogate`] — one fit per
//! (k, topology), amortized across the grid — falling back to the full
//! simulator wherever the surrogate refuses.

use super::Options;
use crate::config::{ArchConfig, NopConfig, NopMode};
use crate::coordinator::par_map;
use crate::dnn::by_name;
use crate::mapping::{ChipletPartition, Mapping};
use crate::noc::sim::{FlowSpec, Mode};
use crate::nop::sim::{analytical_latency, saturation_rate, uniform_nop_flows, NopSim};
use crate::nop::topology::{NopNetwork, NopTopology};
use crate::util::{fmt_sig, Table};

/// The `nop-congestion` experiment generator.
pub fn nop_congestion(opts: &Options) -> Result<Vec<Table>, String> {
    let nop = NopConfig::default();
    let ks: Vec<usize> = if opts.fast {
        vec![4]
    } else {
        vec![4, 8, 16, 25]
    };
    let measure: u64 = if opts.fast { 3_000 } else { 6_000 };
    let seed = opts.seed;
    let nop_mode = opts.nop_mode;

    // --- 1. Uniform steady sweep, driver-parallelized over (k, topo) -----
    let points: Vec<(usize, NopTopology)> = ks
        .iter()
        .flat_map(|&k| NopTopology::all().into_iter().map(move |t| (k, t)))
        .collect();
    let rows = par_map(&points, None, |&(k, topo)| {
        let net = NopNetwork::build(topo, k);
        let flows = uniform_nop_flows(k, 0.02);
        let ana = analytical_latency(&net, &nop, &flows);
        // Surrogate mode answers from the fitted curve; every other mode
        // — and any surrogate refusal — runs the flit simulator.
        let surrogate = if nop_mode == NopMode::Surrogate {
            crate::sim::surrogate::steady_latency(topo, k, &nop, 0.02, seed)
        } else {
            None
        };
        let sim_lat = match surrogate {
            Some(lat) => lat,
            None => {
                NopSim::new(
                    topo,
                    k,
                    &nop,
                    &flows,
                    Mode::Steady {
                        warmup: 500,
                        measure,
                    },
                    seed,
                )
                .run()
                .avg_latency
            }
        };
        let sat = saturation_rate(topo, k, &nop, seed);
        (k, topo, ana, sim_lat, sat)
    });
    let mut sweep = Table::new(
        "NoP congestion — low-load latency (NoP cycles) and saturation rate, uniform traffic",
        &[
            "chiplets",
            "NoP",
            "analytical",
            "sim_low_load",
            "err_%",
            "sat_rate_flit/chiplet/cyc",
        ],
    );
    for (k, topo, ana, sim_lat, sat) in rows {
        let err = 100.0 * (sim_lat - ana).abs() / ana.max(1e-9);
        sweep.add_row(vec![
            k.to_string(),
            topo.name().into(),
            fmt_sig(ana, 4),
            fmt_sig(sim_lat, 4),
            fmt_sig(err, 3),
            match sat {
                Some(rate) => fmt_sig(rate, 3),
                None => ">1.0".into(),
            },
        ]);
    }

    // --- 2. DNN-driven drain: a real partition's package traffic ---------
    let model = if opts.fast { "NiN" } else { "VGG-19" };
    let g = by_name(model).ok_or_else(|| {
        format!(
            "unknown DNN '{model}' (valid: {})",
            crate::dnn::valid_names()
        )
    })?;
    let arch = ArchConfig::reram();
    let mapping = Mapping::build(&g, &arch);
    let mut drain = Table::new(
        format!("NoP drain — one frame of {model}'s inter-chiplet traffic (NoP cycles)"),
        &["chiplets", "NoP", "flows", "flits", "makespan", "drained"],
    );
    // Partition once per k (serial — cheap), then fan the (k × topology)
    // drains out over the driver. Makespans are memoized process-wide, so
    // repeat runs (benches, CLI re-invocations in one process) are free.
    let drain_points: Vec<(usize, Vec<FlowSpec>, NopTopology)> = ks
        .iter()
        .map(|&k| {
            let part = ChipletPartition::build(&g, &mapping, &arch, k);
            let flows: Vec<FlowSpec> = part
                .nop_flows(nop.link_width)
                .into_iter()
                .map(|(s, d, flits)| FlowSpec {
                    src: s,
                    dst: d,
                    rate: 0.0,
                    flits,
                })
                .collect();
            (k, flows)
        })
        .flat_map(|(k, flows)| {
            NopTopology::all()
                .into_iter()
                .map(move |t| (k, flows.clone(), t))
        })
        .collect();
    let drain_rows = par_map(&drain_points, None, |(k, flows, topo)| {
        let total: u64 = flows.iter().map(|f| f.flits).sum();
        let budget = 10_000 + total.saturating_mul(64);
        let estimate = if nop_mode == NopMode::Surrogate {
            crate::sim::surrogate::drain_estimate(*topo, *k, &nop, flows, seed)
        } else {
            None
        };
        let (makespan, drained) = match estimate {
            Some(est) => (est.min(budget), est <= budget),
            None => {
                let stats = crate::sim::memo::drain_makespan(*topo, *k, &nop, flows, budget, seed);
                (stats.makespan, stats.drained)
            }
        };
        vec![
            k.to_string(),
            topo.name().into(),
            flows.len().to_string(),
            total.to_string(),
            makespan.to_string(),
            drained.to_string(),
        ]
    });
    for row in drain_rows {
        drain.add_row(row);
    }

    Ok(vec![sweep, drain])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::CommBackend;

    fn fast_opts() -> Options {
        Options {
            fast: true,
            backend: CommBackend::Analytical,
            ..Options::default()
        }
    }

    #[test]
    fn low_load_rows_agree_with_analytical_within_15pct() {
        let tables = nop_congestion(&fast_opts()).unwrap();
        let sweep = &tables[0];
        assert_eq!(sweep.rows.len(), 3); // k = 4 x three topologies
        for row in &sweep.rows {
            let err: f64 = row[4].parse().unwrap();
            assert!(err < 15.0, "{} k={}: {err}% off analytical", row[1], row[0]);
        }
    }

    #[test]
    fn surrogate_mode_reproduces_the_sweep_shape() {
        // Same grid priced from the fitted curves: the low-load rows stay
        // near analytical (the surrogate's first anchor is low-load) and
        // every drain row still terminates.
        let opts = Options {
            nop_mode: NopMode::Surrogate,
            ..fast_opts()
        };
        let tables = nop_congestion(&opts).unwrap();
        assert_eq!(tables[0].rows.len(), 3);
        for row in &tables[0].rows {
            let err: f64 = row[4].parse().unwrap();
            assert!(err < 20.0, "{} k={}: {err}% off analytical", row[1], row[0]);
        }
        assert_eq!(tables[1].rows.len(), 3);
        for row in &tables[1].rows {
            assert_eq!(row[5], "true", "{} k={} did not drain", row[1], row[0]);
            let makespan: u64 = row[4].parse().unwrap();
            assert!(makespan > 0);
        }
    }

    #[test]
    fn dnn_drain_terminates_on_every_topology() {
        let tables = nop_congestion(&fast_opts()).unwrap();
        let drain = &tables[1];
        assert_eq!(drain.rows.len(), 3);
        for row in &drain.rows {
            assert_eq!(row[5], "true", "{} k={} did not drain", row[1], row[0]);
            let makespan: u64 = row[4].parse().unwrap();
            let flits: u64 = row[3].parse().unwrap();
            assert!(flits > 0, "partition produced no package traffic");
            assert!(makespan > 0);
        }
    }
}
