//! Experiment registry: one generator per paper figure/table. Each
//! generator returns [`Table`]s whose rows/series match what the paper
//! reports; `repro figure <n>` / `repro table <n>` print them.
//!
//! See DESIGN.md §5 for the experiment index and EXPERIMENTS.md for the
//! recorded paper-vs-measured outcomes.

pub mod ablations;
pub mod fig_analytical;
pub mod fig_chiplet;
pub mod fig_congestion;
pub mod fig_density;
pub mod fig_edap;
pub mod fig_nop_congestion;
pub mod fig_p2p;
pub mod fig_serving;
pub mod fig_workload;
pub mod tables;

use crate::arch::CommBackend;
use crate::config::NopMode;
use crate::util::Table;

/// Options shared by all experiments.
#[derive(Clone, Debug)]
pub struct Options {
    /// Interconnect backend: `Analytical` (fast, default for the CLI) or
    /// `Simulate` (cycle-accurate, what the paper's BookSim runs did).
    pub backend: CommBackend,
    /// Restrict expensive sweeps to a smaller DNN set.
    pub fast: bool,
    /// Package-leg pricing mode for NoP-bound experiments (`workload`,
    /// `serving`, `nop-congestion`): `Analytical` keeps the seeds'
    /// behavior, `Sim` prices via the flit simulator, `Surrogate` via the
    /// sim-anchored curves of [`crate::sim::surrogate`].
    pub nop_mode: NopMode,
    /// Simulation seed.
    pub seed: u64,
}

impl Default for Options {
    fn default() -> Self {
        Self {
            backend: CommBackend::Analytical,
            fast: false,
            nop_mode: NopMode::Analytical,
            seed: 0x1AC5_EED,
        }
    }
}

/// One registered experiment. Generators return `Err` with a descriptive
/// message (e.g. an unknown DNN name listing the valid ones) instead of
/// panicking; the CLI surfaces it as a normal command error.
pub struct Experiment {
    /// Canonical id: "fig1" … "fig21", "table2" … "table4".
    pub id: &'static str,
    /// Human-readable title printed above the tables.
    pub title: &'static str,
    /// Generator producing the experiment's tables.
    pub run: fn(&Options) -> Result<Vec<Table>, String>,
}

/// All experiments, in paper order.
pub fn registry() -> Vec<Experiment> {
    vec![
        Experiment {
            id: "fig1",
            title: "Connection density vs number of neurons (model zoo)",
            run: fig_density::fig1,
        },
        Experiment {
            id: "fig3",
            title: "Routing latency share of total latency, P2P IMC",
            run: fig_p2p::fig3,
        },
        Experiment {
            id: "fig5",
            title: "Average latency vs injection bandwidth (64 nodes)",
            run: fig_p2p::fig5,
        },
        Experiment {
            id: "fig8",
            title: "Throughput of P2P / NoC-tree / NoC-mesh (SRAM), normalized to P2P",
            run: fig_p2p::fig8,
        },
        Experiment {
            id: "fig9",
            title: "EDAP of NoC-tree / NoC-mesh / c-mesh",
            run: fig_edap::fig9,
        },
        Experiment {
            id: "fig11",
            title: "Analytical model accuracy vs cycle-accurate simulation",
            run: fig_analytical::fig11,
        },
        Experiment {
            id: "fig12",
            title: "Analytical model speed-up vs cycle-accurate simulation (mesh)",
            run: fig_analytical::fig12,
        },
        Experiment {
            id: "fig13",
            title: "Percentage of queues with zero occupancy at flit arrival",
            run: fig_congestion::fig13,
        },
        Experiment {
            id: "fig14",
            title: "Average occupancy of non-empty queues (NiN, VGG-19)",
            run: fig_congestion::fig14,
        },
        Experiment {
            id: "fig15",
            title: "Average vs worst-case latency per source-destination pair",
            run: fig_congestion::fig15,
        },
        Experiment {
            id: "fig16",
            title: "Normalized throughput and EDAP, NoC-tree vs NoC-mesh (SRAM)",
            run: fig_edap::fig16,
        },
        Experiment {
            id: "fig17",
            title: "Normalized throughput and EDAP, NoC-tree vs NoC-mesh (ReRAM)",
            run: fig_edap::fig17,
        },
        Experiment {
            id: "fig18",
            title: "Virtual-channel sweep: throughput and EDAP (ReRAM)",
            run: fig_edap::fig18,
        },
        Experiment {
            id: "fig19",
            title: "Bus-width sweep: throughput and EDAP (ReRAM)",
            run: fig_edap::fig19,
        },
        Experiment {
            id: "fig20",
            title: "Optimal NoC topology regions (density vs neurons)",
            run: fig_density::fig20,
        },
        Experiment {
            id: "fig21",
            title: "Total latency vs connection density, P2P vs NoC",
            run: fig_p2p::fig21,
        },
        Experiment {
            id: "ablation-adc",
            title: "Ablation: flash-ADC resolution sweep",
            run: ablations::ablation_adc,
        },
        Experiment {
            id: "ablation-buffers",
            title: "Ablation: router buffer-depth sweep",
            run: ablations::ablation_buffers,
        },
        Experiment {
            id: "ablation-pe",
            title: "Ablation: crossbar (PE) size sweep",
            run: ablations::ablation_pe,
        },
        Experiment {
            id: "topologies",
            title: "Topology exploration: all six interconnects",
            run: ablations::topology_exploration,
        },
        Experiment {
            id: "chiplet",
            title: "Multi-chiplet scale-out: NoC+NoP sweep and joint recommendation",
            run: fig_chiplet::chiplet,
        },
        Experiment {
            id: "nop-congestion",
            title: "NoP congestion: flit-level package simulation vs analytical model",
            run: fig_nop_congestion::nop_congestion,
        },
        Experiment {
            id: "serving",
            title: "Chiplet-aware serving: policy x package sweep with modeled p50/p99",
            run: fig_serving::serving,
        },
        Experiment {
            id: "workload",
            title: "Multi-model serving: placement x admission x arrival shape, hit-rate headline",
            run: fig_workload::workload,
        },
        Experiment {
            id: "table2",
            title: "Design parameters",
            run: tables::table2,
        },
        Experiment {
            id: "table3",
            title: "MAPD of worst-case vs average NoC latency",
            run: fig_congestion::table3,
        },
        Experiment {
            id: "table4",
            title: "VGG-19 inference vs state-of-the-art accelerators",
            run: tables::table4,
        },
    ]
}

/// Look an experiment up by id ("fig16", "16", "table4", ...).
pub fn find(id: &str) -> Option<Experiment> {
    let want = id.to_ascii_lowercase();
    registry().into_iter().find(|e| {
        e.id == want
            || e.id.strip_prefix("fig") == Some(want.as_str())
            || e.id.strip_prefix("table") == Some(want.as_str())
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_covers_every_paper_artifact() {
        let ids: Vec<&str> = registry().iter().map(|e| e.id).collect();
        for want in [
            "fig1", "fig3", "fig5", "fig8", "fig9", "fig11", "fig12", "fig13", "fig14", "fig15",
            "fig16", "fig17", "fig18", "fig19", "fig20", "fig21", "table2", "table3", "table4",
        ] {
            assert!(ids.contains(&want), "missing {want}");
        }
    }

    #[test]
    fn find_accepts_bare_numbers() {
        assert_eq!(find("16").unwrap().id, "fig16");
        assert_eq!(find("fig16").unwrap().id, "fig16");
        assert_eq!(find("table4").unwrap().id, "table4");
        assert!(find("99").is_none());
    }
}
