//! Beyond-the-paper experiment: multi-chiplet scale-out. Sweeps the
//! hierarchical (chiplet count × NoP topology) space for the eval-set DNNs
//! and reports the joint (chiplets, NoP, NoC) recommendation per model —
//! the package-level analogue of Fig. 20.

use super::Options;
use crate::arch::{recommend_scaleout, recommend_topology};
use crate::config::{ArchConfig, NocConfig, NopConfig, SimConfig};
use crate::dnn::{eval_set, DnnGraph};
use crate::nop::evaluator::evaluate_package;
use crate::nop::topology::NopTopology;
use crate::util::{fmt_sig, Table};

fn eval_dnns(opts: &Options) -> Vec<DnnGraph> {
    if opts.fast {
        eval_set()
            .into_iter()
            .filter(|g| g.total_macs() < 1_000_000_000)
            .collect()
    } else {
        eval_set()
    }
}

/// The scale-out sweep: per DNN, end-to-end latency and EDAP for packages
/// of 2/4/8 chiplets under each NoP topology (per-chiplet NoC chosen by
/// the single-chip advisor), plus the joint recommendation table.
pub fn chiplet(opts: &Options) -> Result<Vec<Table>, String> {
    let arch = ArchConfig::reram();
    let base_noc = NocConfig::default();
    let base_nop = NopConfig::default();
    let sim = SimConfig {
        seed: opts.seed,
        ..SimConfig::default()
    };

    let dnns = eval_dnns(opts);
    let mut sweep = Table::new(
        "Chiplet scale-out — end-to-end latency (ms) / EDAP (J·ms·mm²) per NoP topology",
        &[
            "dnn", "chiplets", "NoC", "P2P", "ring", "mesh", "best NoP",
        ],
    );
    for g in &dnns {
        let noc_topo = recommend_topology(g, &arch, &base_noc).topology;
        let noc = NocConfig {
            topology: noc_topo,
            ..base_noc.clone()
        };
        for k in [2usize, 4, 8] {
            let evals: Vec<_> = NopTopology::all()
                .into_iter()
                .map(|t| {
                    let nop = NopConfig {
                        topology: t,
                        chiplets: k,
                        ..base_nop.clone()
                    };
                    evaluate_package(g, &arch, &noc, &nop, &sim, opts.backend)
                })
                .collect();
            let best = evals
                .iter()
                .min_by(|a, b| a.edap().total_cmp(&b.edap()))
                .unwrap();
            let cell = |i: usize| {
                format!(
                    "{} / {}",
                    fmt_sig(evals[i].latency_s() * 1e3, 3),
                    fmt_sig(evals[i].edap(), 3)
                )
            };
            sweep.add_row(vec![
                g.name.clone(),
                k.to_string(),
                noc_topo.name().into(),
                cell(0),
                cell(1),
                cell(2),
                best.nop_topology.name().into(),
            ]);
        }
    }

    let mut rec_table = Table::new(
        "Joint scale-out recommendation (EDAP-optimal chiplets × NoP × NoC)",
        &[
            "dnn",
            "chiplets",
            "NoP",
            "NoC",
            "latency_ms",
            "EDAP",
            "cross_kbits",
        ],
    );
    for g in &dnns {
        let rec = recommend_scaleout(g, &arch, &base_noc, &base_nop);
        rec_table.add_row(vec![
            g.name.clone(),
            rec.chiplets.to_string(),
            if rec.chiplets == 1 {
                "-".into()
            } else {
                rec.nop_topology.name().into()
            },
            rec.noc_topology.name().into(),
            fmt_sig(rec.best.latency_s() * 1e3, 4),
            fmt_sig(rec.best.edap(), 3),
            fmt_sig(rec.best.cross_bits as f64 / 1e3, 3),
        ]);
    }

    Ok(vec![sweep, rec_table])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chiplet_experiment_runs_fast() {
        let opts = Options {
            fast: true,
            ..Options::default()
        };
        let tables = chiplet(&opts).unwrap();
        assert_eq!(tables.len(), 2);
        assert!(!tables[0].rows.is_empty());
        assert!(!tables[1].rows.is_empty());
    }
}
