//! # imcnoc — interconnect-aware in-memory-computing DNN accelerator simulator
//!
//! Reproduction of *"Impact of On-Chip Interconnect on In-Memory Acceleration
//! of Deep Neural Networks"* (Krishnan, Mandal, Chakrabarti, Seo, Ogras, Cao —
//! ACM JETC 2021, DOI 10.1145/3460233).
//!
//! The crate is the Layer-3 coordinator of a three-layer rust + JAX + Pallas
//! stack:
//!
//! * **L1** (`python/compile/kernels/`) — a Pallas kernel that functionally
//!   models the IMC crossbar hot-spot (bit-serial inputs, per-bitline 4-bit
//!   flash-ADC quantization, shift-and-add recombination).
//! * **L2** (`python/compile/model.py`) — JAX forward passes built on the
//!   kernel, AOT-lowered to HLO text in `artifacts/`.
//! * **L3** (this crate) — everything the paper's evaluation needs:
//!   * [`dnn`] — DNN layer graphs + connection-density accounting (Fig. 1/2),
//!   * [`mapping`] — crossbar/tile mapping (Eq. 2) and injection matrices (Eq. 3),
//!   * [`circuit`] — NeuroSim-class circuit-level estimator for SRAM/ReRAM tiles,
//!   * [`sim`] — the shared flit-level event engine (traffic sources, run
//!     loops, statistics) both cycle simulators adapt, plus process-wide
//!     memo caches for simulator-backed sweeps,
//!   * [`noc`] — BookSim-class cycle-accurate NoC simulator (P2P, tree, mesh,
//!     c-mesh, torus, hypercube) plus the analytical model of Algorithm 2,
//!   * [`nop`] — network-on-package scale-out: packages of IMC chiplets
//!     (P2P / ring / mesh NoP) evaluated hierarchically, reusing the `noc`
//!     machinery per chiplet,
//!   * [`arch`] — end-to-end architecture evaluation (latency/energy/area/EDAP),
//!     the heterogeneous-interconnect architecture of Fig. 10, and the joint
//!     (chiplets, NoP, NoC) scale-out advisor,
//!   * [`baselines`] — ISAAC / PipeLayer / AtomLayer / P2P-IMC comparators,
//!   * [`runtime`] — PJRT loader executing the AOT artifacts from rust,
//!   * [`coordinator`] — parallel sweep driver, batched inference serving,
//!     and the single-/multi-model chiplet serving schedulers,
//!   * [`workload`] — multi-model serving workloads: DNN mixes with
//!     deadlines, bursty/diurnal arrival generators, record/replay traces,
//!     and NoP-aware replica placement,
//!   * [`telemetry`] — zero-cost-when-disabled observability: per-link
//!     flit counters and heatmaps, request lifecycle spans, and
//!     Chrome-trace (Perfetto) export,
//!   * [`experiments`] — one generator per paper figure/table.
//!
//! See `DESIGN.md` for the per-experiment index and `EXPERIMENTS.md` for
//! paper-vs-measured results.

#![warn(missing_docs)]

pub mod arch;
pub mod baselines;
pub mod circuit;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dnn;
pub mod experiments;
pub mod mapping;
pub mod noc;
pub mod nop;
pub mod runtime;
pub mod sim;
pub mod telemetry;
pub mod util;
pub mod workload;

pub use arch::evaluator::{evaluate, ArchEvaluation};
pub use config::{
    Admission, ArchConfig, MemTech, NocConfig, NopConfig, NopMode, ServingConfig, SimConfig,
    WorkloadConfig,
};
pub use dnn::{model_zoo, DnnGraph};
pub use noc::topology::Topology;
pub use nop::{evaluate_package, NopEvaluation, NopTopology};
