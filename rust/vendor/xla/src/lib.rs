//! API-stub of the `xla-rs` PJRT binding used by `imcnoc::runtime`.
//!
//! The offline build image ships neither the xla-rs crate nor the native
//! PJRT CPU plugin. This stub keeps the `--features pjrt` configuration
//! *compiling* with the exact call surface the runtime uses; every
//! constructor fails at run time with [`Error::Unavailable`]. Installing a
//! real binding is a drop-in replacement: point the `xla` path dependency
//! in rust/Cargo.toml at it and rebuild.

use std::fmt;
use std::path::Path;

/// Errors surfaced by the stub.
#[derive(Debug)]
pub enum Error {
    /// The native XLA/PJRT library is not present in this build.
    Unavailable,
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "XLA/PJRT native backend unavailable (offline stub build; \
             install xla-rs and repoint rust/vendor/xla)"
        )
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (stub: cannot be constructed).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Err(Error::Unavailable)
    }

    pub fn platform_name(&self) -> String {
        "stub".into()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable)
    }
}

/// Compiled executable handle (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable)
    }
}

/// Device buffer handle (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::Unavailable)
    }
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: impl AsRef<Path>) -> Result<Self> {
        Err(Error::Unavailable)
    }
}

/// XLA computation wrapper (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> Self {
        XlaComputation { _private: () }
    }
}

/// Host literal (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Self {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Err(Error::Unavailable)
    }

    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(Error::Unavailable)
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::Unavailable)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_reports_unavailable() {
        assert!(PjRtClient::cpu().is_err());
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        assert!(Literal::vec1(&[1.0]).reshape(&[1]).is_err());
        let msg = Error::Unavailable.to_string();
        assert!(msg.contains("unavailable"));
    }
}
