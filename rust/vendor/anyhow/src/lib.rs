//! Minimal drop-in subset of the `anyhow` error-handling API for the
//! offline build environment (no registry access — see rust/Cargo.toml).
//!
//! Supported surface, matching what this repository uses:
//!
//! * [`Error`] — an opaque error with a context chain,
//! * [`Result<T>`] — alias for `Result<T, Error>`,
//! * [`anyhow!`] / [`bail!`] — ad-hoc error construction,
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result` and
//!   `Option`,
//! * `From<E: std::error::Error>` so `?` converts library errors,
//! * `{:#}` alternate formatting printing the whole cause chain
//!   (`outer: inner: root`), like real anyhow.

use std::fmt;

/// An error with an outermost-first chain of context messages.
pub struct Error {
    /// chain[0] is the outermost (most recently attached) message.
    chain: Vec<String>,
}

/// `Result` specialized to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from any displayable message.
    pub fn msg(message: impl fmt::Display) -> Self {
        Error {
            chain: vec![message.to_string()],
        }
    }

    /// Attach an outer context message.
    pub fn context(mut self, context: impl fmt::Display) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The cause chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The innermost (root) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` prints the full chain like anyhow.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain.first().map(|s| s.as_str()).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.join(": "))
    }
}

// NOTE: `Error` itself intentionally does NOT implement `std::error::Error`,
// exactly like real anyhow — that is what makes this blanket impl coherent.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Return early with an [`Error`] when a condition fails.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($t:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn macro_forms() {
        let a: Error = anyhow!("plain");
        assert_eq!(a.to_string(), "plain");
        let name = "x";
        let b: Error = anyhow!("value {name} {}", 3);
        assert_eq!(b.to_string(), "value x 3");
        let c: Error = anyhow!(String::from("owned"));
        assert_eq!(c.to_string(), "owned");
    }

    #[test]
    fn bail_and_question_mark() {
        fn f() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 1");

        fn g() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(g().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_format() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(e.to_string(), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
        assert_eq!(e.root_cause(), "gone");

        let o: Option<u8> = None;
        let oe = o.with_context(|| "missing").unwrap_err();
        assert_eq!(oe.to_string(), "missing");
    }
}
