//! CLI surface integration: every fast experiment generator runs through
//! the public `cli::run` entry point without touching PJRT.

use imcnoc::cli::run;

fn argv(parts: &[&str]) -> Vec<String> {
    parts.iter().map(|s| s.to_string()).collect()
}

#[test]
fn list_and_help() {
    run(&argv(&["list"])).unwrap();
    run(&argv(&["help"])).unwrap();
}

#[test]
fn config_show_and_load() {
    run(&argv(&["config"])).unwrap();
    let path = std::env::temp_dir().join("imcnoc_cli_cfg.ini");
    std::fs::write(&path, "[arch]\npe_size = 128\n").unwrap();
    run(&argv(&["config", "--load", path.to_str().unwrap()])).unwrap();
    assert!(run(&argv(&["config", "--load", "/nonexistent.ini"])).is_err());
}

#[test]
fn figures_fast_analytical() {
    // Cheap figures end to end through the CLI (fast + analytical).
    for id in ["1", "20"] {
        run(&argv(&["figure", id, "--fast"])).unwrap();
    }
    run(&argv(&["table", "2", "--fast"])).unwrap();
    run(&argv(&["table", "4", "--fast"])).unwrap();
}

#[test]
fn eval_and_advise() {
    run(&argv(&["eval", "LeNet-5", "--tech", "sram", "--topology", "tree"])).unwrap();
    run(&argv(&["eval", "MLP", "--verbose"])).unwrap();
    run(&argv(&["advise", "VGG-19"])).unwrap();
    assert!(run(&argv(&["eval", "NoSuchNet"])).is_err());
    assert!(run(&argv(&["eval", "MLP", "--tech", "flash"])).is_err());
    assert!(run(&argv(&["eval", "MLP", "--topology", "ring"])).is_err());
}

#[test]
fn chiplet_subcommand_and_experiment() {
    // The acceptance-criteria surface: one model across all NoP topologies,
    // and the registered scale-out experiment through the figure runner.
    run(&argv(&["chiplet", "--model", "lenet5", "--chiplets", "4"])).unwrap();
    run(&argv(&["figure", "chiplet", "--fast"])).unwrap();
    assert!(run(&argv(&["chiplet", "--model", "lenet5", "--nop", "torus"])).is_err());
}

#[test]
fn chiplet_sim_mode_and_nop_congestion_experiment() {
    // `--sim` drives the flit-level NoP co-simulation end to end and the
    // congestion experiment smoke-runs at k = 4 under --fast.
    run(&argv(&["chiplet", "--model", "MLP", "--chiplets", "2", "--sim"])).unwrap();
    run(&argv(&["figure", "nop-congestion", "--fast"])).unwrap();
}

#[test]
fn serve_modeled_and_serving_experiment() {
    // The CI smoke run: `repro serve --fast` = SqueezeNet on 4 mesh
    // chiplets under the congestion-aware policy, no PJRT required.
    run(&argv(&["serve", "--fast"])).unwrap();
    // The registered serving experiment through the figure runner.
    run(&argv(&["figure", "serving", "--fast"])).unwrap();
    // A modeled run with explicit routing flags, including `--sim`
    // (flit-level NoP ingress pricing).
    run(&argv(&[
        "serve",
        "--model",
        "MLP",
        "--chiplets",
        "2",
        "--topology",
        "ring",
        "--policy",
        "least-latency",
        "--requests",
        "32",
        "--sim",
    ]))
    .unwrap();
    assert!(run(&argv(&["serve", "--model", "MLP", "--policy", "psychic"])).is_err());
}

#[test]
fn serve_mix_and_workload_experiment() {
    // The tier-1 CI smoke run: the default VGG-19 + SqueezeNet mix with
    // NoP-aware placement and deadline-aware admission, small config.
    run(&argv(&["serve", "--mix", "--fast"])).unwrap();
    // The registered multi-model workload experiment through the figure
    // runner.
    run(&argv(&["figure", "workload", "--fast"])).unwrap();
    // Record a trace on a cheap mix, then replay it through the CLI.
    let path = std::env::temp_dir().join("imcnoc_cli_integration.trace");
    let path = path.to_str().unwrap().to_string();
    run(&argv(&[
        "serve",
        "--mix",
        "MLP:1:0,LeNet-5:1:0",
        "--chiplets",
        "2",
        "--topology",
        "ring",
        "--requests",
        "40",
        "--record-trace",
        path.as_str(),
    ]))
    .unwrap();
    run(&argv(&[
        "serve",
        "--trace",
        path.as_str(),
        "--chiplets",
        "2",
        "--topology",
        "ring",
    ]))
    .unwrap();
    // Bad specs surface as errors, not panics.
    assert!(run(&argv(&["serve", "--mix", "NoSuchNet:1:0"])).is_err());
    assert!(run(&argv(&["serve", "--mix", "--placement", "magic"])).is_err());
}

#[test]
fn serve_trace_out_and_chiplet_heatmap() {
    // Telemetry surfaces end to end: `--trace-out` writes a Perfetto-
    // loadable Chrome trace reconciling with the report, `--heatmap`
    // renders the per-topology NoP link grids.
    let path = std::env::temp_dir().join("imcnoc_cli_integration_trace.json");
    let path = path.to_str().unwrap().to_string();
    run(&argv(&["serve", "--fast", "--trace-out", path.as_str()])).unwrap();
    let json = std::fs::read_to_string(&path).unwrap();
    assert!(json.contains("\"traceEvents\""), "not a chrome trace");
    assert!(json.contains("\"completed\""), "missing reconciliation meta");
    run(&argv(&["chiplet", "--model", "MLP", "--heatmap"])).unwrap();
    // --heatmap-out writes one file, so the topology must be pinned.
    assert!(run(&argv(&["chiplet", "--model", "MLP", "--heatmap-out", "/tmp/x"])).is_err());
}

#[test]
fn serve_mix_metrics_out_smoke() {
    // The tier-1 CI smoke run for the time-series surface: the default
    // mix under --fast writes a windowed metrics document that the
    // scripts/check_metrics.py gate can reconcile.
    let path = std::env::temp_dir().join("imcnoc_cli_integration_metrics.json");
    let path = path.to_str().unwrap().to_string();
    run(&argv(&["serve", "--mix", "--fast", "--metrics-out", path.as_str()])).unwrap();
    let json = std::fs::read_to_string(&path).unwrap();
    assert!(json.contains("\"windows\""), "no windows array");
    assert!(json.contains("\"totals\""), "no totals object");
    assert!(json.contains("\"drift_events\""), "no drift array");
}

#[test]
fn unknown_inputs_error_cleanly() {
    assert!(run(&argv(&["figure", "99"])).is_err());
    assert!(run(&argv(&["table"])).is_err());
    assert!(run(&argv(&["bogus-command"])).is_err());
}
