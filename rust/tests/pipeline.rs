//! Cross-module integration: the full evaluation pipeline (DNN → mapping →
//! circuit → NoC → metrics) and the paper's qualitative claims as
//! executable assertions.

use imcnoc::arch::{evaluate, recommend_topology, CommBackend, HeteroArchitecture};
use imcnoc::baselines;
use imcnoc::config::{ArchConfig, MemTech, NocConfig, SimConfig};
use imcnoc::coordinator::Driver;
use imcnoc::dnn::{by_name, eval_set, models};
use imcnoc::noc::topology::Topology;

fn quick_eval(name: &str, topo: Topology, tech: MemTech) -> imcnoc::ArchEvaluation {
    let g = by_name(name).unwrap();
    let arch = ArchConfig {
        tech,
        ..ArchConfig::default()
    };
    evaluate(
        &g,
        topo,
        &arch,
        &NocConfig::with_topology(topo),
        &SimConfig::default(),
        CommBackend::Analytical,
    )
}

#[test]
fn full_eval_set_produces_consistent_metrics() {
    for g in eval_set() {
        for topo in [Topology::P2P, Topology::Tree, Topology::Mesh] {
            let e = evaluate(
                &g,
                topo,
                &ArchConfig::reram(),
                &NocConfig::with_topology(topo),
                &SimConfig::default(),
                CommBackend::Analytical,
            );
            assert!(e.latency_s() > 0.0, "{} {topo:?}", g.name);
            assert!(e.energy_j() > 0.0);
            assert!(e.area_mm2() > 0.0);
            assert!(e.edap() > 0.0);
            assert!(e.comm_latency_s >= 0.0);
            assert!(
                (e.latency_s() - e.compute_latency_s - e.comm_latency_s).abs()
                    < 1e-12,
                "latency decomposition must be exact"
            );
        }
    }
}

#[test]
fn paper_claim_noc_beats_p2p_at_density() {
    // Fig. 8 / Fig. 21 direction: for dense DNNs the NoC architectures
    // must deliver strictly higher FPS than P2P.
    for name in ["ResNet-50", "DenseNet-100"] {
        let p2p = quick_eval(name, Topology::P2P, MemTech::Sram);
        let mesh = quick_eval(name, Topology::Mesh, MemTech::Sram);
        assert!(
            mesh.fps() > p2p.fps(),
            "{name}: mesh {} vs p2p {}",
            mesh.fps(),
            p2p.fps()
        );
    }
}

#[test]
fn paper_claim_tree_wins_edap_for_compact() {
    // Fig. 16(b)/17(b): low-density DNNs have lower EDAP on NoC-tree.
    for name in ["MLP", "LeNet-5"] {
        for tech in [MemTech::Sram, MemTech::Reram] {
            let tree = quick_eval(name, Topology::Tree, tech);
            let mesh = quick_eval(name, Topology::Mesh, tech);
            assert!(
                tree.edap() < mesh.edap(),
                "{name} {tech:?}: tree {} vs mesh {}",
                tree.edap(),
                mesh.edap()
            );
        }
    }
}

#[test]
fn paper_claim_advisor_matches_eval_split() {
    // §6.4: the guidance assigns the paper's compact group to tree and the
    // dense group to mesh.
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    for (name, want) in [
        ("MLP", Topology::Tree),
        ("LeNet-5", Topology::Tree),
        ("ResNet-50", Topology::Mesh),
        ("VGG-19", Topology::Mesh),
        ("DenseNet-100", Topology::Mesh),
    ] {
        let g = by_name(name).unwrap();
        let rec = recommend_topology(&g, &arch, &noc);
        assert_eq!(rec.topology, want, "{name} (density {})", rec.density);
    }
}

#[test]
fn paper_claim_table4_headlines() {
    let rows = baselines::table4_rows(CommBackend::Analytical);
    let ours = &rows[1]; // Proposed-ReRAM
    assert!(ours.edap < baselines::atomlayer().edap / 2.0);
    assert!(ours.fps > baselines::atomlayer().fps);
    assert!(ours.power_w < baselines::pipelayer().power_w / 100.0);
    assert!(ours.latency_ms < baselines::isaac().latency_ms);
}

#[test]
fn hetero_architecture_end_to_end() {
    let hw = HeteroArchitecture::new(ArchConfig::reram());
    let e = hw.evaluate(&models::vgg(19), CommBackend::Analytical);
    assert_eq!(e.topology, Topology::Mesh);
    assert!(e.fps() > 100.0, "VGG-19 FPS {}", e.fps());
}

#[test]
fn driver_parallel_sweep_matches_serial() {
    let driver = Driver::new();
    let points: Vec<_> = ["MLP", "NiN"]
        .iter()
        .flat_map(|n| {
            [Topology::Tree, Topology::Mesh].into_iter().map(|t| {
                (
                    n.to_string(),
                    ArchConfig::default(),
                    NocConfig::with_topology(t),
                    CommBackend::Analytical,
                )
            })
        })
        .collect();
    let par = driver.evaluate_many(&points).unwrap();
    for (r, (name, arch, noc, backend)) in par.iter().zip(&points) {
        let g = by_name(name).unwrap();
        let serial = evaluate(
            &g,
            noc.topology,
            arch,
            noc,
            &SimConfig::default(),
            *backend,
        );
        assert_eq!(r.comm_cycles, serial.comm_cycles, "{name}");
        assert_eq!(r.tiles, serial.tiles);
    }
}

#[test]
fn simulate_backend_agrees_with_analytical_direction() {
    // The cycle-accurate backend must preserve the tree-vs-mesh EDAP
    // direction for a compact DNN.
    let tree_a = quick_eval("LeNet-5", Topology::Tree, MemTech::Reram);
    let g = by_name("LeNet-5").unwrap();
    let tree_s = evaluate(
        &g,
        Topology::Tree,
        &ArchConfig::reram(),
        &NocConfig::with_topology(Topology::Tree),
        &SimConfig::default(),
        CommBackend::Simulate,
    );
    // Same mapping/compute; comm estimates within 3x of each other.
    assert_eq!(tree_a.tiles, tree_s.tiles);
    let ratio = tree_s.comm_cycles as f64 / tree_a.comm_cycles.max(1) as f64;
    assert!(
        (0.33..3.0).contains(&ratio),
        "backend divergence: sim {} vs ana {}",
        tree_s.comm_cycles,
        tree_a.comm_cycles
    );
}
