//! PJRT round-trip integration: load the AOT artifacts produced by
//! `make artifacts`, execute from rust, and validate the functional
//! contract (shapes, determinism, quantized-vs-float agreement).
//!
//! Tests skip (pass vacuously, with a note) when artifacts are missing so
//! `cargo test` works before the first `make artifacts`; the Makefile
//! always builds artifacts first. They also skip on default (stub) builds
//! without the `pjrt` feature — see `imcnoc::runtime::pjrt_enabled`.

use imcnoc::coordinator::server::{argmax, synthetic_requests, InferenceServer};
use imcnoc::runtime::{artifact_available, artifact_path, pjrt_enabled, Runtime};

fn need_artifacts(names: &[&str]) -> bool {
    if !pjrt_enabled() {
        eprintln!("skipping: built without the `pjrt` feature (stub runtime)");
        return false;
    }
    for n in names {
        if !artifact_available(n) {
            eprintln!("skipping: artifact '{n}' missing (run `make artifacts`)");
            return false;
        }
    }
    true
}

#[test]
fn mlp_artifact_round_trip() {
    if !need_artifacts(&["mlp"]) {
        return;
    }
    let mut rt = Runtime::cpu().expect("PJRT client");
    let model = rt.load(artifact_path("mlp")).expect("compile artifact");
    let x: Vec<f32> = (0..8 * 784).map(|i| (i % 255) as f32 / 255.0).collect();
    let out = model.run_f32(&[(&x, &[8, 784])]).expect("execute");
    assert_eq!(out.len(), 1, "MLP returns a 1-tuple");
    assert_eq!(out[0].len(), 8 * 10);
    assert!(out[0].iter().all(|v| v.is_finite()));
    // Determinism.
    let out2 = model.run_f32(&[(&x, &[8, 784])]).expect("execute");
    assert_eq!(out[0], out2[0]);
}

#[test]
fn lenet_artifact_round_trip() {
    if !need_artifacts(&["lenet"]) {
        return;
    }
    let mut rt = Runtime::cpu().expect("PJRT client");
    let model = rt.load(artifact_path("lenet")).expect("compile artifact");
    let x: Vec<f32> = (0..4 * 784).map(|i| ((i * 7) % 100) as f32 / 100.0).collect();
    let out = model.run_f32(&[(&x, &[4, 784])]).expect("execute");
    assert_eq!(out[0].len(), 4 * 10);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn quantized_agrees_with_float_twin() {
    if !need_artifacts(&["mlp", "mlp_float"]) {
        return;
    }
    let mut server = InferenceServer::new(8).expect("server");
    let requests = synthetic_requests(64, 784, 42);
    let imc = server
        .serve(artifact_path("mlp"), &requests, 784)
        .expect("imc serve");
    let flt = server
        .serve(artifact_path("mlp_float"), &requests, 784)
        .expect("float serve");
    let agree = imc
        .outputs
        .iter()
        .zip(&flt.outputs)
        .filter(|(a, b)| argmax(a) == argmax(b))
        .count();
    let frac = agree as f64 / imc.outputs.len() as f64;
    assert!(
        frac > 0.5,
        "IMC/float agreement {frac} too low ({agree}/{})",
        imc.outputs.len()
    );
}

#[test]
fn serving_reports_sane_statistics() {
    if !need_artifacts(&["mlp_float"]) {
        return;
    }
    let mut server = InferenceServer::new(8).expect("server");
    let requests = synthetic_requests(10, 784, 7); // partial last batch (8 + 2)
    let report = server
        .serve(artifact_path("mlp_float"), &requests, 784)
        .expect("serve");
    assert_eq!(report.requests, 10);
    assert_eq!(report.completed, 10);
    assert_eq!(report.dropped, 0);
    assert_eq!(report.batches, 2); // 8 + 2(padded)
    assert_eq!(report.outputs.len(), 10);
    assert!(report.mean_ms > 0.0);
    assert!(report.p99_ms >= report.p50_ms);
    assert!(report.throughput_rps > 0.0);
}

#[test]
fn bad_input_shape_is_rejected() {
    if !need_artifacts(&["mlp_float"]) {
        return;
    }
    let mut rt = Runtime::cpu().expect("client");
    let model = rt.load(artifact_path("mlp_float")).expect("compile");
    let x = vec![0.0f32; 10];
    assert!(model.run_f32(&[(&x, &[8, 784])]).is_err());
}
