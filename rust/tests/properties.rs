//! Property-based tests (hand-rolled harness, `imcnoc::util::proptest`) on
//! the system's core invariants: flit conservation, routing minimality,
//! latency monotonicity, mapping soundness, queueing-model sanity, EDAP
//! positivity, and config round-trips.

use imcnoc::config::{
    Admission, ArchConfig, Config, NocConfig, NopConfig, NopMode, ServingConfig, SimConfig,
    TelemetryConfig, WorkloadConfig,
};
use imcnoc::coordinator::mix::{serve_mix_metrics, serve_mix_traced, MixScheduler, MixServingModel};
use imcnoc::coordinator::scheduler::{ChipletScheduler, Policy, ServingModel};
use imcnoc::dnn::{model_zoo, models};
use imcnoc::mapping::{ChipletPartition, InjectionMatrix, Mapping};
use imcnoc::noc::sim::{FlowSpec, Mode, NocSim};
use imcnoc::noc::topology::{Network, Topology};
use imcnoc::noc::AnalyticalModel;
use imcnoc::nop::sim::{analytical_latency, saturation_rate, uniform_nop_flows, NopSim};
use imcnoc::nop::topology::{NopNetwork, NopTopology};
use imcnoc::telemetry::sketch::RELATIVE_ERROR;
use imcnoc::telemetry::{spans_to_trace, QuantileSketch};
use imcnoc::util::percentile;
use imcnoc::util::proptest::check;
use imcnoc::workload::{ArrivalKind, ArrivalProcess, PlacementPolicy, Trace, WorkloadMix};

fn random_flows(
    g: &mut imcnoc::util::proptest::Gen,
    terminals: usize,
    max_flits: u64,
) -> Vec<FlowSpec> {
    let n_flows = g.usize_in(1, 12);
    (0..n_flows)
        .map(|_| {
            let src = g.usize_in(0, terminals - 1);
            let mut dst = g.usize_in(0, terminals - 1);
            if dst == src {
                dst = (dst + 1) % terminals;
            }
            FlowSpec {
                src,
                dst,
                rate: 0.0,
                flits: g.usize_in(1, max_flits as usize) as u64,
            }
        })
        .collect()
}

#[test]
fn prop_flit_conservation_all_topologies() {
    check("flit-conservation", 60, |g| {
        let topo = *g.pick(&Topology::all());
        let terminals = g.usize_in(2, 40);
        let flows = random_flows(g, terminals, 40);
        let expected: u64 = flows
            .iter()
            .filter(|f| f.src != f.dst)
            .map(|f| f.flits)
            .sum();
        let cfg = NocConfig::default();
        let stats = NocSim::new(
            topo,
            terminals,
            &cfg,
            &flows,
            Mode::Drain {
                max_cycles: 10_000 + expected * 128,
            },
            g.u64(),
        )
        .run();
        if !stats.drained {
            return Err(format!("{topo:?} did not drain"));
        }
        if stats.injected != expected || stats.delivered != expected {
            return Err(format!(
                "{topo:?}: injected {} delivered {} expected {expected}",
                stats.injected, stats.delivered
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_route_paths_minimal_and_symmetric_hops() {
    check("route-minimality", 80, |g| {
        let topo = *g.pick(&[Topology::Mesh, Topology::Torus, Topology::Hypercube]);
        let n = g.usize_in(2, 64);
        let net = Network::build(topo, n);
        let a = g.usize_in(0, n - 1);
        let b = g.usize_in(0, n - 1);
        let hops = net.hops(a, b);
        // Deterministic minimal routing on symmetric topologies: the hop
        // count must be symmetric and zero iff same attach router.
        if net.hops(b, a) != hops {
            return Err(format!("{topo:?}: asymmetric hops {a}<->{b}"));
        }
        if (hops == 0) != (net.attach[a] == net.attach[b]) {
            return Err("zero hops must mean same router".into());
        }
        // Paths never exceed the router count.
        if hops >= net.routers.max(1) * 2 {
            return Err(format!("path too long: {hops}"));
        }
        Ok(())
    });
}

#[test]
fn prop_noc_routing_reaches_without_cycles_within_bound() {
    // For every NoC topology and any size, deterministic routing from any
    // source reaches the destination, never revisits a router (no cycles),
    // and stays within a topology-size hop bound.
    check("noc-routing-reachability", 120, |g| {
        let topo = *g.pick(&Topology::all());
        let n = g.usize_in(1, 70);
        let net = Network::build(topo, n);
        let s = g.usize_in(0, n - 1);
        let d = g.usize_in(0, n - 1);
        let path = net.route_path(s, d);
        if *path.first().unwrap() != net.attach[s] || *path.last().unwrap() != net.attach[d] {
            return Err(format!("{topo:?}: path endpoints wrong for {s}->{d}"));
        }
        let mut seen = std::collections::HashSet::new();
        for &r in &path {
            if !seen.insert(r) {
                return Err(format!("{topo:?}: router {r} revisited on {s}->{d}"));
            }
        }
        if path.len() - 1 > net.routers {
            return Err(format!(
                "{topo:?}: {} hops exceeds router count {}",
                path.len() - 1,
                net.routers
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_nop_sim_flit_conservation_and_credit_invariants() {
    // The flit-level package simulator, over random topologies, sizes and
    // drain workloads: everything injected is delivered, the network
    // drains, credits never go negative, and every credit returns to its
    // buffer once the network is empty.
    check("nop-flit-conservation", 60, |g| {
        let topo = *g.pick(&NopTopology::all());
        let k = g.usize_in(2, 25);
        let flows = random_flows(g, k, 60);
        let expected: u64 = flows
            .iter()
            .filter(|f| f.src != f.dst)
            .map(|f| f.flits)
            .sum();
        let cfg = NopConfig::default();
        let (stats, audit) = NopSim::new(
            topo,
            k,
            &cfg,
            &flows,
            Mode::Drain {
                max_cycles: 50_000 + expected * 256,
            },
            g.u64(),
        )
        .run_audited();
        if !stats.drained {
            return Err(format!("{topo:?} k={k} did not drain"));
        }
        if stats.injected != expected || stats.delivered != expected {
            return Err(format!(
                "{topo:?} k={k}: injected {} delivered {} expected {expected}",
                stats.injected, stats.delivered
            ));
        }
        if audit.min_credit < 0 {
            return Err(format!("credit went negative: {}", audit.min_credit));
        }
        if audit.credits.iter().any(|&c| c != audit.capacity) {
            return Err(format!(
                "credits leaked after drain: {:?} (capacity {})",
                audit.credits, audit.capacity
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_nop_sim_low_load_matches_analytical_within_15pct() {
    // At low load the flit simulator must track the analytical package
    // model on every NoP topology — the calibration contract that makes
    // the congestion gap at high load meaningful.
    check("nop-low-load-agreement", 9, |g| {
        let topo = *g.pick(&NopTopology::all());
        let k = g.usize_in(4, 20);
        let cfg = NopConfig::default();
        let net = NopNetwork::build(topo, k);
        let flows = uniform_nop_flows(k, 0.02);
        let ana = analytical_latency(&net, &cfg, &flows);
        let stats = NopSim::new(
            topo,
            k,
            &cfg,
            &flows,
            Mode::Steady {
                warmup: 500,
                measure: 6_000,
            },
            g.u64(),
        )
        .run();
        if stats.delivered == 0 {
            return Err(format!("{topo:?} k={k}: nothing delivered"));
        }
        let err = (stats.avg_latency - ana).abs() / ana;
        if err > 0.15 {
            return Err(format!(
                "{topo:?} k={k}: sim {} vs analytical {ana} ({:.1}% off)",
                stats.avg_latency,
                100.0 * err
            ));
        }
        Ok(())
    });
}

#[test]
fn nop_congestion_gap_appears_only_in_sim_mode() {
    // Acceptance contract: at k = 16 the ring-vs-mesh congestion gap is a
    // sim-only phenomenon. The analytical package latency is injection-rate
    // independent by construction; the flit simulator saturates the
    // 2-link-bisection ring strictly before the 4x4 mesh.
    let cfg = NopConfig::default();
    for topo in [NopTopology::Ring, NopTopology::Mesh] {
        let net = NopNetwork::build(topo, 16);
        let lo = analytical_latency(&net, &cfg, &uniform_nop_flows(16, 0.02));
        let hi = analytical_latency(&net, &cfg, &uniform_nop_flows(16, 0.8));
        assert!(
            (lo - hi).abs() < 1e-9,
            "{topo:?}: analytical latency moved with load ({lo} vs {hi})"
        );
    }
    let ring = saturation_rate(NopTopology::Ring, 16, &cfg, 3)
        .expect("16-chiplet ring must saturate below rate 1.0");
    let mesh = saturation_rate(NopTopology::Mesh, 16, &cfg, 3).unwrap_or(1.04);
    assert!(ring < mesh, "ring saturates at {ring}, mesh at {mesh}");
}

#[test]
fn prop_nop_routing_reaches_without_cycles_within_bound() {
    // Same contract one hierarchy level up, for every NoP topology.
    check("nop-routing-reachability", 120, |g| {
        let topo = *g.pick(&NopTopology::all());
        let k = g.usize_in(1, 24);
        let net = NopNetwork::build(topo, k);
        let s = g.usize_in(0, k - 1);
        let d = g.usize_in(0, k - 1);
        let path = net.route_path(s, d);
        if *path.first().unwrap() != s || *path.last().unwrap() != d {
            return Err(format!("{topo:?}: path endpoints wrong for {s}->{d}"));
        }
        let mut seen = std::collections::HashSet::new();
        for &c in &path {
            if !seen.insert(c) {
                return Err(format!("{topo:?}: chiplet {c} revisited on {s}->{d}"));
            }
        }
        let hops = path.len() - 1;
        if hops != net.hops(s, d) {
            return Err(format!("{topo:?}: path length {hops} != hops()"));
        }
        if hops > net.hop_bound() {
            return Err(format!(
                "{topo:?}: {hops} hops exceeds bound {}",
                net.hop_bound()
            ));
        }
        Ok(())
    });
}

#[test]
fn topology_names_roundtrip_through_parse() {
    // Satellite contract: `parse(t.name())` is identity for both the NoC
    // and the NoP topology enums.
    for t in Topology::all() {
        assert_eq!(Topology::parse(t.name()), Some(t), "NoC {t:?}");
    }
    for t in NopTopology::all() {
        assert_eq!(NopTopology::parse(t.name()), Some(t), "NoP {t:?}");
    }
}

#[test]
fn prop_chiplet_partition_invariants() {
    let zoo = model_zoo();
    check("chiplet-partition-invariants", 30, |g| {
        let graph = g.pick(&zoo);
        let arch = ArchConfig::default();
        let m = Mapping::build(graph, &arch);
        let k = g.usize_in(1, 12);
        let p = ChipletPartition::build(graph, &m, &arch, k);
        p.validate(&m).map_err(|e| format!("{} k={k}: {e}", graph.name))?;
        // Cross-traffic matrix agrees with the cut accounting and has an
        // empty diagonal.
        let x = p.cross_traffic();
        let mut total = 0u64;
        for (i, row) in x.iter().enumerate() {
            if row[i] != 0 {
                return Err(format!("{}: self-traffic on chiplet {i}", graph.name));
            }
            total += row.iter().sum::<u64>();
        }
        if total != p.cut_bits() {
            return Err(format!(
                "{}: cross matrix {total} != cut bits {}",
                graph.name,
                p.cut_bits()
            ));
        }
        if k == 1 && total != 0 {
            return Err("single chiplet must have no cross traffic".into());
        }
        Ok(())
    });
}

#[test]
fn prop_steady_latency_monotone_in_rate() {
    check("latency-monotonicity", 10, |g| {
        let seed = g.u64();
        let cfg = NocConfig::default();
        let run = |rate: f64| {
            let flows = imcnoc::noc::sim::uniform_random_flows(16, rate);
            NocSim::new(
                Topology::Mesh,
                16,
                &cfg,
                &flows,
                Mode::Steady {
                    warmup: 500,
                    measure: 4_000,
                },
                seed,
            )
            .run()
            .avg_latency
        };
        let lo = run(0.02);
        let hi = run(0.35);
        // Allow small sampling noise, but high load must not be faster.
        if hi + 1.0 < lo {
            return Err(format!("latency decreased with load: {lo} -> {hi}"));
        }
        Ok(())
    });
}

#[test]
fn prop_mapping_invariants_across_configs() {
    let zoo = model_zoo();
    check("mapping-invariants", 40, |g| {
        let graph = g.pick(&zoo);
        let arch = ArchConfig {
            pe_size: *g.pick(&[64usize, 128, 256, 512]),
            n_bits: *g.pick(&[4usize, 8]),
            pes_per_ce: g.usize_in(1, 8),
            ces_per_tile: g.usize_in(1, 8),
            ..ArchConfig::default()
        };
        let m = Mapping::build(graph, &arch);
        m.validate(&arch).map_err(|e| format!("{}: {e}", graph.name))?;
        if m.layers.len() != graph.num_weight_layers() {
            return Err("every weight layer must map".into());
        }
        // No layer is split across tiles it does not own; tiles cover
        // crossbars exactly once (contiguity checked by validate()).
        let total: usize = m.layers.iter().map(|lt| lt.count).sum();
        if total != m.total_tiles {
            return Err("tile count mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_injection_rates_nonnegative_and_scale() {
    let zoo = model_zoo();
    check("injection-scaling", 30, |g| {
        let graph = g.pick(&zoo);
        let arch = ArchConfig::default();
        let m = Mapping::build(graph, &arch);
        let w = *g.pick(&[16usize, 32, 64, 128]);
        let noc = NocConfig {
            bus_width: w,
            ..NocConfig::default()
        };
        let inj = InjectionMatrix::build(graph, &m, &arch, &noc);
        for f in &inj.flows {
            if !(f.rate >= 0.0 && f.rate.is_finite()) {
                return Err(format!("bad rate {}", f.rate));
            }
        }
        // Total rate scales inversely with bus width.
        let noc2 = NocConfig {
            bus_width: w * 2,
            ..NocConfig::default()
        };
        let inj2 = InjectionMatrix::build(graph, &m, &arch, &noc2);
        let (r1, r2) = (inj.total_rate(), inj2.total_rate());
        if (r1 - 2.0 * r2).abs() > 1e-9 * r1.max(1.0) {
            return Err(format!("rate scaling broken: {r1} vs {r2}"));
        }
        Ok(())
    });
}

#[test]
fn prop_analytical_model_sane() {
    check("analytical-sanity", 40, |g| {
        let topo = *g.pick(&[Topology::Mesh, Topology::Tree]);
        let n = g.usize_in(4, 64);
        let net = Network::build(topo, n);
        let cfg = NocConfig::default();
        let model = AnalyticalModel::new(&net, &cfg);
        let rate = g.f64_in(0.001, 0.2);
        let flows = imcnoc::noc::sim::uniform_random_flows(n, rate);
        let est = model.layer_latency(&flows);
        if !(est.avg_latency.is_finite() && est.avg_latency >= 0.0) {
            return Err(format!("bad latency {}", est.avg_latency));
        }
        if est.total_waiting < -1e-9 {
            return Err(format!("negative waiting {}", est.total_waiting));
        }
        let (bottleneck, transit) = model.layer_bottleneck(&flows);
        if bottleneck < 0.0 || transit < 0.0 {
            return Err("negative bottleneck/transit".into());
        }
        Ok(())
    });
}

#[test]
fn prop_config_ini_roundtrip() {
    check("config-roundtrip", 50, |g| {
        let cfg = Config {
            arch: ArchConfig {
                pe_size: *g.pick(&[64usize, 128, 256, 512]),
                n_bits: *g.pick(&[4usize, 8, 16]),
                adc_bits: g.usize_in(1, 12),
                fps: g.f64_in(1.0, 1000.0).round(),
                ..ArchConfig::default()
            },
            noc: NocConfig {
                topology: *g.pick(&Topology::all()),
                bus_width: *g.pick(&[16usize, 32, 64]),
                virtual_channels: g.usize_in(1, 8),
                buffer_depth: g.usize_in(1, 32),
                pipeline_stages: g.usize_in(1, 8),
                ..NocConfig::default()
            },
            nop: NopConfig {
                topology: *g.pick(&NopTopology::all()),
                mode: *g.pick(&[NopMode::Analytical, NopMode::Sim]),
                chiplets: g.usize_in(1, 64),
                link_width: *g.pick(&[8usize, 16, 32, 64]),
                hop_latency_cycles: g.usize_in(1, 64) as u64,
                buffer_flits: g.usize_in(2, 128),
                energy_pj_per_bit: g.f64_in(0.1, 8.0).round(),
                ..NopConfig::default()
            },
            serving: ServingConfig {
                policy: *g.pick(&Policy::all()),
                queue_depth: g.usize_in(1, 256),
                arrival_rps: g.f64_in(0.0, 10_000.0).round(),
                requests: g.usize_in(1, 10_000),
                batch: g.usize_in(1, 64),
                seed: g.u64(),
            },
            workload: WorkloadConfig {
                mix: WorkloadMix::parse("MLP:2:25,LeNet-5:1:inf,NiN:3:0").unwrap(),
                arrival: *g.pick(&ArrivalKind::all()),
                placement: *g.pick(&PlacementPolicy::all()),
                admission: *g.pick(&Admission::all()),
                burst_factor: g.f64_in(1.0, 2.0).round(),
                on_fraction: 0.25,
                cycle_s: 0.05,
                frames_alpha: g.f64_in(0.0, 2.0).round(),
                frames_max: g.usize_in(1, 16),
            },
            telemetry: TelemetryConfig {
                enabled: *g.pick(&[false, true]),
                trace_out: "trace.json".to_string(),
                heatmap: *g.pick(&[false, true]),
                window_ms: g.f64_in(0.0, 100.0).round(),
                metrics_out: "metrics.json".to_string(),
            },
            sim: Default::default(),
        };
        let parsed = Config::from_ini(&cfg.to_ini()).map_err(|e| e.to_string())?;
        if parsed != cfg {
            return Err("round-trip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mix_serving_conserves_requests_across_policies_and_generators() {
    // The multi-model scheduler over random routing policies, admission
    // controls, arrival generators (Poisson / bursty / diurnal, with and
    // without heavy-tailed frames) and loads: offered == completed +
    // dropped + shed, globally and per model; per-chiplet served counts
    // close the books; shedding only happens under deadline-aware
    // admission; queues respect their depth.
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let mix = WorkloadMix::parse("MLP:2:0,LeNet-5:1:0").unwrap();
    // Model builds are expensive (each runs a NoP saturation sweep):
    // prebuild two package points and randomize everything else.
    let built: Vec<MixServingModel> = [
        (3usize, NopTopology::Ring, PlacementPolicy::RoundRobin),
        (5usize, NopTopology::Mesh, PlacementPolicy::NopAware),
    ]
    .iter()
    .map(|&(k, topo, placement)| {
        let nop = NopConfig {
            topology: topo,
            chiplets: k,
            ..NopConfig::default()
        };
        MixServingModel::build(&mix, placement, &arch, &noc, &nop, &sim).unwrap()
    })
    .collect();
    check("mix-serving-conservation", 12, |g| {
        let model = g.pick(&built).clone();
        let proc = ArrivalProcess {
            kind: *g.pick(&ArrivalKind::all()),
            // on_fraction <= 0.5 and factor <= 2 keeps factor*fraction <= 1.
            burst_factor: g.f64_in(1.0, 2.0),
            on_fraction: g.f64_in(0.1, 0.5),
            cycle_s: g.f64_in(0.005, 0.05),
            frames_alpha: *g.pick(&[0.0, 1.5]),
            frames_max: 6,
        };
        proc.validate()?;
        let rate = model.capacity_rps(proc.mean_frames()) * g.f64_in(0.2, 3.0);
        let requests = g.usize_in(10, 120);
        let events = proc.generate(&mix, rate, requests, g.u64());
        let cfg = ServingConfig {
            policy: *g.pick(&Policy::all()),
            queue_depth: g.usize_in(1, 8),
            requests,
            ..ServingConfig::default()
        };
        let admission = *g.pick(&Admission::all());
        let mut sched = MixScheduler::new(model, &cfg, admission);
        let report = sched.run(&events);
        if report.requests != requests {
            return Err(format!("report requests {} != {requests}", report.requests));
        }
        if report.completed + report.dropped + report.shed != report.requests {
            return Err(format!(
                "requests {} != completed {} + dropped {} + shed {}",
                report.requests, report.completed, report.dropped, report.shed
            ));
        }
        if admission == Admission::DropOnFull && report.shed != 0 {
            return Err(format!("drop-on-full shed {}", report.shed));
        }
        let served: usize = report.per_chiplet.iter().map(|s| s.served).sum();
        if served != report.completed {
            return Err(format!("served {served} != completed {}", report.completed));
        }
        let mut sums = (0usize, 0usize, 0usize, 0usize);
        for pm in &report.per_model {
            if pm.offered != pm.completed + pm.dropped + pm.shed {
                return Err(format!(
                    "{}: offered {} != {} + {} + {}",
                    pm.model, pm.offered, pm.completed, pm.dropped, pm.shed
                ));
            }
            if pm.deadline_hits > pm.deadline_offered || pm.deadline_offered > pm.offered {
                return Err(format!(
                    "{}: hits {} / deadline-offered {} / offered {}",
                    pm.model, pm.deadline_hits, pm.deadline_offered, pm.offered
                ));
            }
            sums.0 += pm.offered;
            sums.1 += pm.completed;
            sums.2 += pm.dropped;
            sums.3 += pm.shed;
        }
        if sums != (report.requests, report.completed, report.dropped, report.shed) {
            return Err(format!("per-model sums {sums:?} do not close the books"));
        }
        for s in &report.per_chiplet {
            if s.peak_queue > cfg.queue_depth {
                return Err(format!(
                    "peak queue {} > depth {}",
                    s.peak_queue, cfg.queue_depth
                ));
            }
            if !(0.0..=1.0).contains(&s.utilization) {
                return Err(format!("utilization {}", s.utilization));
            }
        }
        if report.p99_ms < report.p50_ms {
            return Err(format!("p99 {} < p50 {}", report.p99_ms, report.p50_ms));
        }
        // Tentpole contract: the windowed time-series closes the books
        // against the report exactly — totals, per-window sums, per-window
        // model splits, and per-model sums across windows.
        let ts = sched.timeseries();
        let expect = (
            report.requests as u64,
            report.completed as u64,
            report.dropped as u64,
            report.shed as u64,
        );
        if ts.totals() != expect {
            return Err(format!(
                "time-series totals {:?} != report {expect:?}",
                ts.totals()
            ));
        }
        let mut win = (0u64, 0u64, 0u64, 0u64);
        let mut per_model = vec![(0u64, 0u64); ts.model_names().len()];
        for w in ts.windows() {
            let m_arr: u64 = w.models.iter().map(|m| m.arrivals).sum();
            let m_comp: u64 = w.models.iter().map(|m| m.completions).sum();
            if m_arr != w.arrivals || m_comp != w.completions {
                return Err(format!(
                    "window model splits ({m_arr}, {m_comp}) != window counters ({}, {})",
                    w.arrivals, w.completions
                ));
            }
            win.0 += w.arrivals;
            win.1 += w.completions;
            win.2 += w.drops;
            win.3 += w.sheds;
            for (acc, m) in per_model.iter_mut().zip(&w.models) {
                acc.0 += m.arrivals;
                acc.1 += m.completions;
            }
        }
        if win != expect {
            return Err(format!("window sums {win:?} != report {expect:?}"));
        }
        for (pm, acc) in report.per_model.iter().zip(&per_model) {
            if (pm.offered as u64, pm.completed as u64) != *acc {
                return Err(format!(
                    "{}: summed windows {acc:?} != per-model ({}, {})",
                    pm.model, pm.offered, pm.completed
                ));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_sketch_quantiles_within_documented_error_bound() {
    // Tentpole contract: the streaming log-bucket sketch reproduces any
    // quantile of an arbitrary positive sample set within its documented
    // relative-error bound of the exact sort-based percentile, at any
    // sample count (including n = 1, where every quantile is that sample).
    check("sketch-quantile-error", 60, |g| {
        let n = g.usize_in(1, 400);
        let mut xs = Vec::with_capacity(n);
        let mut sk = QuantileSketch::new();
        for _ in 0..n {
            // Log-uniform over six decades — microsecond to minute
            // latencies in ms, the sketch's intended dynamic range.
            let v = 10f64.powf(g.f64_in(-3.0, 3.0));
            xs.push(v);
            sk.record(v);
        }
        if sk.count() != n as u64 {
            return Err(format!("count {} != {n}", sk.count()));
        }
        for p in [0.0, 10.0, 50.0, 90.0, 99.0, 100.0] {
            let exact = percentile(&xs, p);
            let got = sk.quantile(p);
            let tol = RELATIVE_ERROR * exact.abs() + 1e-12;
            if (got - exact).abs() > tol {
                return Err(format!("p{p}: sketch {got} vs exact {exact} (tol {tol})"));
            }
        }
        Ok(())
    });
}

#[test]
fn mix_replay_determinism_byte_for_byte() {
    // Acceptance contract: recording a bursty heavy-tailed workload to the
    // text trace format and replaying it reproduces the serving report
    // byte-for-byte (the scheduler draws no randomness of its own, and the
    // trace's shortest-round-trip floats are bit-exact).
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let nop = NopConfig {
        topology: NopTopology::Mesh,
        chiplets: 4,
        ..NopConfig::default()
    };
    let serving = ServingConfig {
        requests: 200,
        seed: 0xDEAD_BEEF,
        ..ServingConfig::default()
    };
    let workload = WorkloadConfig {
        mix: WorkloadMix::parse("MLP:1:0,LeNet-5:1:0").unwrap(),
        arrival: ArrivalKind::Bursty,
        frames_alpha: 1.5,
        ..WorkloadConfig::default()
    };
    let (_, trace, original) =
        imcnoc::coordinator::mix::serve_mix(&arch, &noc, &nop, &sim, &serving, &workload)
            .unwrap();
    // Same seed + config regenerates the identical trace and report.
    let (_, trace2, again) =
        imcnoc::coordinator::mix::serve_mix(&arch, &noc, &nop, &sim, &serving, &workload)
            .unwrap();
    assert_eq!(trace2, trace);
    assert_eq!(format!("{again:?}"), format!("{original:?}"));
    // The text round trip is bit-exact, and replaying it reproduces the
    // report byte-for-byte.
    let parsed = Trace::parse(&trace.to_text()).unwrap();
    assert_eq!(parsed, trace);
    let (_, replayed) =
        imcnoc::coordinator::mix::replay_mix(&parsed, &arch, &noc, &nop, &sim, &serving, &workload)
            .unwrap();
    assert_eq!(format!("{replayed:?}"), format!("{original:?}"));
    // A different serving seed produces a different workload (the serving
    // seed is live and independent of [sim] seed).
    let reseeded = ServingConfig {
        seed: 0xBEEF,
        ..serving.clone()
    };
    let (_, trace3, _) =
        imcnoc::coordinator::mix::serve_mix(&arch, &noc, &nop, &sim, &reseeded, &workload)
            .unwrap();
    assert_ne!(trace3.events, trace.events);
}

#[test]
fn prop_serving_scheduler_conserves_requests() {
    // The chiplet-aware serving scheduler over random policies, package
    // sizes and loads: every request is either completed or dropped,
    // per-chiplet served counts close the books, queues never exceed
    // their depth, and utilization stays in [0, 1].
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = imcnoc::config::SimConfig::default();
    // Model builds are expensive (each runs a NoP saturation sweep):
    // prebuild two package sizes and randomize everything else.
    let built: Vec<_> = [2usize, 5]
        .iter()
        .map(|&k| {
            let nop = NopConfig {
                topology: NopTopology::Ring,
                chiplets: k,
                ..NopConfig::default()
            };
            ServingModel::build(&models::lenet5(), &arch, &noc, &nop, &sim)
        })
        .collect();
    check("serving-conservation", 12, |g| {
        let (model, part) = g.pick(&built).clone();
        let cfg = ServingConfig {
            policy: *g.pick(&Policy::all()),
            queue_depth: g.usize_in(1, 8),
            arrival_rps: model.capacity_rps(1) * g.f64_in(0.2, 3.0),
            requests: g.usize_in(10, 120),
            batch: g.usize_in(1, 4),
            ..ServingConfig::default()
        };
        let mut sched = ChipletScheduler::new(model, part, &cfg);
        let report = sched.run(&cfg, g.u64());
        if report.completed + report.dropped != report.requests {
            return Err(format!(
                "requests {} != completed {} + dropped {}",
                report.requests, report.completed, report.dropped
            ));
        }
        let served: usize = report.per_chiplet.iter().map(|s| s.served).sum();
        if served != report.completed {
            return Err(format!("served {served} != completed {}", report.completed));
        }
        for s in &report.per_chiplet {
            if s.peak_queue > cfg.queue_depth {
                return Err(format!(
                    "peak queue {} > depth {}",
                    s.peak_queue, cfg.queue_depth
                ));
            }
            if !(0.0..=1.0).contains(&s.utilization) {
                return Err(format!("utilization {}", s.utilization));
            }
        }
        if report.p99_ms < report.p50_ms {
            return Err(format!("p99 {} < p50 {}", report.p99_ms, report.p50_ms));
        }
        Ok(())
    });
}

#[test]
fn prop_telemetry_link_counters_conserve_flits() {
    // Satellite contract: under random drain workloads the instrumented
    // per-endpoint flit counters reconcile exactly with the `SimStats`
    // totals, on both the NoC and the NoP flit simulator.
    check("telemetry-conservation", 30, |g| {
        let topo = *g.pick(&Topology::all());
        let terminals = g.usize_in(2, 30);
        let flows = random_flows(g, terminals, 30);
        let expected: u64 = flows.iter().map(|f| f.flits).sum();
        let cfg = NocConfig::default();
        let (stats, telem) = NocSim::new(
            topo,
            terminals,
            &cfg,
            &flows,
            Mode::Drain {
                max_cycles: 10_000 + expected * 128,
            },
            g.u64(),
        )
        .instrument(true)
        .run_instrumented();
        if !stats.drained {
            return Err(format!("NoC {topo:?} did not drain"));
        }
        if telem.injected_total() != stats.injected || telem.ejected_total() != stats.delivered {
            return Err(format!(
                "NoC {topo:?}: telem {}/{} vs stats {}/{}",
                telem.injected_total(),
                telem.ejected_total(),
                stats.injected,
                stats.delivered
            ));
        }
        if telem.cycles != stats.cycles {
            return Err(format!("NoC cycles {} != {}", telem.cycles, stats.cycles));
        }

        let nop_topo = *g.pick(&NopTopology::all());
        let k = g.usize_in(2, 20);
        let nop_flows = random_flows(g, k, 40);
        let nop_expected: u64 = nop_flows.iter().map(|f| f.flits).sum();
        let nop_cfg = NopConfig::default();
        let (nop_stats, nop_telem) = NopSim::new(
            nop_topo,
            k,
            &nop_cfg,
            &nop_flows,
            Mode::Drain {
                max_cycles: 50_000 + nop_expected * 256,
            },
            g.u64(),
        )
        .instrument(true)
        .run_instrumented();
        if !nop_stats.drained {
            return Err(format!("NoP {nop_topo:?} k={k} did not drain"));
        }
        let (inj, ej) = (nop_telem.injected_total(), nop_telem.ejected_total());
        if inj != nop_stats.injected || ej != nop_stats.delivered {
            return Err(format!(
                "NoP {nop_topo:?} k={k}: telem {inj}/{ej} vs stats {}/{}",
                nop_stats.injected, nop_stats.delivered
            ));
        }
        // Every cross-chiplet flit traverses at least one package link.
        if nop_telem.transit_total() < nop_stats.delivered {
            return Err(format!(
                "NoP link transits {} < delivered {}",
                nop_telem.transit_total(),
                nop_stats.delivered
            ));
        }
        Ok(())
    });
}

#[test]
fn trace_export_deterministic_for_identical_seed() {
    // Satellite contract: an identical `[serving] seed` yields a
    // byte-identical Chrome-trace export (lifecycle spans are derived from
    // the deterministic serving clock; no hidden randomness).
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let nop = NopConfig {
        topology: NopTopology::Ring,
        chiplets: 2,
        ..NopConfig::default()
    };
    let serving = ServingConfig {
        requests: 120,
        seed: 0xFACE,
        ..ServingConfig::default()
    };
    let workload = WorkloadConfig {
        mix: WorkloadMix::parse("MLP:1:0,LeNet-5:1:0").unwrap(),
        arrival: ArrivalKind::Bursty,
        ..WorkloadConfig::default()
    };
    let export = || {
        let (model, _, report, spans) =
            serve_mix_traced(&arch, &noc, &nop, &sim, &serving, &workload).unwrap();
        let names: Vec<&str> = model.models.iter().map(|m| m.name.as_str()).collect();
        let mut tr = spans_to_trace(&spans, &names);
        tr.set_meta("requests", report.requests as u64);
        tr.to_json()
    };
    let first = export();
    let second = export();
    assert!(first.contains("\"traceEvents\""), "not a chrome trace");
    assert!(first.len() > 200, "suspiciously small export: {first}");
    assert_eq!(first, second, "equal seeds must export identical traces");
}

#[test]
fn metrics_export_deterministic_for_identical_seed() {
    // Satellite contract: an identical `[serving] seed` yields a
    // byte-identical `--metrics-out` JSON document (windowed counters,
    // sketch quantiles and drift events are all derived from the
    // deterministic serving clock; floats print at fixed precision).
    let arch = ArchConfig::default();
    let noc = NocConfig::default();
    let sim = SimConfig::default();
    let nop = NopConfig {
        topology: NopTopology::Mesh,
        chiplets: 4,
        ..NopConfig::default()
    };
    let serving = ServingConfig {
        requests: 150,
        seed: 0xFEED,
        ..ServingConfig::default()
    };
    let workload = WorkloadConfig {
        mix: WorkloadMix::parse("MLP:1:0,LeNet-5:1:0").unwrap(),
        arrival: ArrivalKind::Bursty,
        frames_alpha: 1.5,
        ..WorkloadConfig::default()
    };
    let export = || {
        let (_, _, report, _, ts) =
            serve_mix_metrics(&arch, &noc, &nop, &sim, &serving, &workload, 0.0).unwrap();
        ts.to_json(report.requests, report.completed, report.dropped, report.shed)
    };
    let first = export();
    let second = export();
    assert!(first.contains("\"windows\""), "no windows array: {first}");
    assert!(first.contains("\"drift_events\""), "no drift array");
    assert!(first.len() > 200, "suspiciously small export: {first}");
    assert_eq!(first, second, "equal seeds must export identical metrics");
}
