"""Layer 2 — JAX forward passes built on the L1 crossbar kernel.

Two inference networks mirror the rust model zoo's compact members and run
entirely through IMC-crossbar semantics (bit-serial inputs, bit-sliced
weights, 4-bit flash ADC):

* ``mlp_forward``   — 784-512-256-10 MLP (the paper's lowest-density DNN),
* ``lenet_forward`` — LeNet-5-class CNN (conv via im2col -> crossbar
  matmul, exactly how the Eq. 2 mapping lays convolutions onto crossbars).

Float-precision twins (``*_forward_float``) provide the agreement baseline
the e2e example checks. Weights are synthetic but deterministic — the
interconnect study never depends on trained weights, and functional
correctness is defined as IMC-vs-float agreement, not dataset accuracy.

``aot.py`` lowers the jitted forwards to HLO text; the rust runtime
executes them via PJRT. Python never runs at request time.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import imc_crossbar as xbar

MLP_DIMS = (784, 512, 256, 10)


def quantize_activations(x, n_bits=xbar.DEFAULT_N_BITS):
    """Quantize [0, 1] activations to unsigned n-bit codes (int32)."""
    hi = (1 << n_bits) - 1
    return jnp.clip(jnp.round(x * hi), 0, hi).astype(jnp.int32)


def quantize_weights(w, n_bits=xbar.DEFAULT_N_BITS):
    """Symmetric per-tensor weight quantization to signed n-bit codes.

    Returns (w_q int32, scale float) with w ~= w_q * scale.
    """
    hi = float((1 << (n_bits - 1)) - 1)
    scale = jnp.maximum(jnp.max(jnp.abs(w)), 1e-8) / hi
    w_q = jnp.clip(jnp.round(w / scale), -hi - 1, hi).astype(jnp.int32)
    return w_q, scale


def imc_linear(x, w_q, w_scale, *, n_bits=xbar.DEFAULT_N_BITS,
               adc_bits=xbar.DEFAULT_ADC_BITS, pe_size=xbar.DEFAULT_PE,
               interpret=True):
    """One IMC fully-connected layer on [0, 1]-ranged inputs.

    Activations are requantized to n-bit codes at the tile input buffer
    (the paper's I/O buffer), multiplied on the crossbars, and rescaled
    back to real units.
    """
    x_q = quantize_activations(x, n_bits)
    y = xbar.imc_matmul(x_q, w_q, pe_size=pe_size, n_bits=n_bits,
                        adc_bits=adc_bits, interpret=interpret)
    act_scale = 1.0 / float((1 << n_bits) - 1)
    return y * (w_scale * act_scale)


def _glorot(key, shape, sparsity=0.9):
    """Sparse glorot-uniform synthetic weights.

    Trained DNN layers activate only a few bitline cells per read — that is
    precisely why the paper's 4-bit flash ADC loses little accuracy (§5.2).
    Dense i.i.d. random weights would be the adversarial worst case for ADC
    quantization, so the synthetic weights mirror realistic sparsity.
    """
    k1, k2 = jax.random.split(key)
    fan_in, fan_out = shape[0], shape[-1]
    lim = jnp.sqrt(6.0 / (fan_in + fan_out))
    w = jax.random.uniform(k1, shape, jnp.float32, -lim, lim)
    mask = jax.random.uniform(k2, shape) >= sparsity
    return w * mask


def init_mlp_params(seed=0, dims=MLP_DIMS, n_bits=xbar.DEFAULT_N_BITS):
    """Deterministic synthetic MLP weights, pre-quantized for the IMC path."""
    keys = jax.random.split(jax.random.PRNGKey(seed), len(dims) - 1)
    params = []
    for key, (d_in, d_out) in zip(keys, zip(dims[:-1], dims[1:])):
        w = _glorot(key, (d_in, d_out))
        w_q, scale = quantize_weights(w, n_bits)
        params.append({"w": w, "w_q": w_q, "scale": scale})
    return params


@partial(jax.jit, static_argnames=("n_bits", "adc_bits", "pe_size", "interpret"))
def mlp_forward(params_q, x, *, n_bits=xbar.DEFAULT_N_BITS,
                adc_bits=xbar.DEFAULT_ADC_BITS, pe_size=xbar.DEFAULT_PE,
                interpret=True):
    """IMC-quantized MLP forward: x (batch, 784) in [0,1] -> logits.

    ``params_q`` is a list of (w_q, scale) leaves (jit-friendly).
    """
    h = x
    last = len(params_q) - 1
    for i, (w_q, scale) in enumerate(params_q):
        h = imc_linear(h, w_q, scale, n_bits=n_bits, adc_bits=adc_bits,
                       pe_size=pe_size, interpret=interpret)
        if i != last:
            # ReLU + renormalize into the next tile's input range.
            h = jnp.maximum(h, 0.0)
            h = h / jnp.maximum(jnp.max(h), 1e-6)
    return (h,)


def mlp_forward_float(params, x):
    """Float-precision twin of ``mlp_forward`` (same normalization)."""
    h = x
    last = len(params) - 1
    for i, p in enumerate(params):
        h = h @ p["w"]
        if i != last:
            h = jnp.maximum(h, 0.0)
            h = h / jnp.maximum(jnp.max(h), 1e-6)
    return (h,)


def params_q(params):
    """Extract the jit-friendly quantized leaves."""
    return [(p["w_q"], p["scale"]) for p in params]


# --- LeNet-5-class CNN -----------------------------------------------------

LENET_CFG = (
    # (kind, ...) layers; shapes follow rust/src/dnn/models/classic.rs
    ("conv", 5, 1, 6),    # 28x28x1 -> 28x28x6 ('same')
    ("pool", 2),          # -> 14x14x6
    ("conv", 5, 6, 16),   # -> 14x14x16
    ("pool", 2),          # -> 7x7x16
    ("fc", 7 * 7 * 16, 120),
    ("fc", 120, 84),
    ("fc", 84, 10),
)


def init_lenet_params(seed=1, n_bits=xbar.DEFAULT_N_BITS):
    params = []
    key = jax.random.PRNGKey(seed)
    for layer in LENET_CFG:
        if layer[0] == "conv":
            _, k, c_in, c_out = layer
            key, sub = jax.random.split(key)
            w = _glorot(sub, (k * k * c_in, c_out))
        elif layer[0] == "fc":
            _, d_in, d_out = layer
            key, sub = jax.random.split(key)
            w = _glorot(sub, (d_in, d_out))
        else:
            params.append(None)
            continue
        w_q, scale = quantize_weights(w, n_bits)
        params.append({"w": w, "w_q": w_q, "scale": scale})
    return params


def _im2col(x, k):
    """(B, H, W, C) -> (B*H*W, k*k*C) patches with 'same' padding.

    This is the Eq. 2 view of a convolution: each output pixel's receptive
    field becomes one crossbar input vector of length Kx*Ky*C_in.
    """
    b, h, w, c = x.shape
    pad = k // 2
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    patches = jax.lax.conv_general_dilated_patches(
        xp.transpose(0, 3, 1, 2),  # NCHW
        filter_shape=(k, k),
        window_strides=(1, 1),
        padding="VALID",
    )  # (B, C*k*k, H, W)
    patches = patches.transpose(0, 2, 3, 1).reshape(b * h * w, c * k * k)
    # conv_general_dilated_patches orders features as (C, k, k); our weight
    # rows are (k, k, C) — reorder to match.
    patches = patches.reshape(-1, c, k * k).transpose(0, 2, 1).reshape(b * h * w, k * k * c)
    return patches


def _run_lenet(params, x, linear):
    """Shared LeNet skeleton; ``linear(h2d, layer_idx)`` does the matmul."""
    b = x.shape[0]
    h = x.reshape(b, 28, 28, 1)
    for i, layer in enumerate(LENET_CFG):
        if layer[0] == "conv":
            k = layer[1]
            bb, hh, ww, cc = h.shape
            cols = _im2col(h, k)
            out = linear(cols, i)
            h = out.reshape(bb, hh, ww, -1)
            h = jnp.maximum(h, 0.0)
            h = h / jnp.maximum(jnp.max(h), 1e-6)
        elif layer[0] == "pool":
            s = layer[1]
            bb, hh, ww, cc = h.shape
            h = h.reshape(bb, hh // s, s, ww // s, s, cc).max(axis=(2, 4))
        else:  # fc
            if h.ndim > 2:
                h = h.reshape(b, -1)
            h = linear(h, i)
            if i != len(LENET_CFG) - 1:
                h = jnp.maximum(h, 0.0)
                h = h / jnp.maximum(jnp.max(h), 1e-6)
    return (h,)


@partial(jax.jit, static_argnames=("n_bits", "adc_bits", "pe_size", "interpret"))
def lenet_forward(params_q_leaves, x, *, n_bits=xbar.DEFAULT_N_BITS,
                  adc_bits=xbar.DEFAULT_ADC_BITS, pe_size=xbar.DEFAULT_PE,
                  interpret=True):
    """IMC-quantized LeNet forward: x (batch, 784) in [0,1] -> logits."""

    def linear(h2d, i):
        w_q, scale = params_q_leaves[i]
        return imc_linear(h2d, w_q, scale, n_bits=n_bits, adc_bits=adc_bits,
                          pe_size=pe_size, interpret=interpret)

    return _run_lenet(None, x, linear)


def lenet_forward_float(params, x):
    def linear(h2d, i):
        return h2d @ params[i]["w"]

    return _run_lenet(params, x, linear)


def lenet_params_q(params):
    """jit-friendly leaves, indexed like LENET_CFG (None for pools)."""
    return [None if p is None else (p["w_q"], p["scale"]) for p in params]
