"""Layer 1 — Pallas kernel functionally modeling the IMC crossbar hot-spot.

Hardware being modeled (paper §2.2/§5.2, Table 2):

* a ``pe_size x pe_size`` crossbar stores 1-bit cells; an ``n_bits`` weight
  occupies ``n_bits`` adjacent columns (bit-sliced, MSB in two's
  complement);
* inputs are applied **bit-serially** (no DAC — sequential 1-bit signaling,
  paper ref. [27]): one 0/1 input bit-plane is asserted on all rows at
  once;
* every bitline's analog population count is digitized by a 4-bit flash
  ADC; shift-and-add recombines weight-bit columns and input bit-planes.

The kernel below computes one *crossbar read* for one input bit-plane
across all row-blocks of a weight matrix: a (M, pe) x (pe, N·n_bits) 0/1
matmul per grid step followed by the ADC transfer function. Everything is
float32 arithmetic over {0,1} values, so the pure-jnp oracle in ``ref.py``
must match bit-exactly.

TPU mapping (DESIGN.md §Hardware-Adaptation): one grid step ≡ one crossbar
PE; BlockSpec tiles the weight matrix into (pe, pe)-sized VMEM blocks the
way tiles hold crossbars; the ADC clamp is VPU work fused behind the MXU
matmul. ``interpret=True`` everywhere — the CPU PJRT client cannot run
Mosaic custom-calls; real-TPU efficiency is estimated in DESIGN.md.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_PE = 256
DEFAULT_N_BITS = 8
DEFAULT_ADC_BITS = 4


def adc_levels(adc_bits: int) -> int:
    """Distinct non-zero output codes of the flash ADC."""
    return (1 << adc_bits) - 1


def adc_delta(pe_size: int, adc_bits: int) -> float:
    """Worst-case ADC step: full scale (= pe_size hits) over the codes."""
    return max(1.0, pe_size / adc_levels(adc_bits))


def column_deltas(w_bits, pe_size: int, adc_bits: int):
    """Per-(row-block, column) ADC step sizes.

    Flash-ADC references are calibrated per column to the column's maximum
    possible population count (the number of programmed cells) — standard
    practice in IMC macros, and what lets a 4-bit ADC digitize sparse
    bitlines with little loss (paper §5.2: "minimum or no accuracy
    degradation").

    Returns (blocks, C) float32; w_bits must already be padded.
    """
    kk, c = w_bits.shape
    blocks = kk // pe_size
    col_max = w_bits.reshape(blocks, pe_size, c).sum(axis=1)
    return jnp.maximum(1.0, col_max / adc_levels(adc_bits))


def _crossbar_kernel(x_ref, w_ref, d_ref, o_ref, *, levels: int):
    """One crossbar read: 0/1 matmul + flash-ADC transfer function."""
    s = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    delta = d_ref[...]  # (1, C) per-column calibrated step
    # Flash ADC: mid-tread uniform quantizer, clipped at full scale.
    q = jnp.clip(jnp.round(s / delta), 0.0, float(levels)) * delta
    o_ref[...] = q[None]  # output block is (1, M, C): one row-block per step


def crossbar_read(x_plane, w_bits, *, pe_size=DEFAULT_PE, adc_bits=DEFAULT_ADC_BITS,
                  interpret=True):
    """Digitized per-row-block partial sums of one input bit-plane.

    Args:
      x_plane: (M, K) float32 of {0, 1} — one input bit-plane.
      w_bits:  (K, C) float32 of {0, 1} — bit-sliced weight columns.
      pe_size: crossbar rows per PE; K is padded up to a multiple.
    Returns:
      (K/pe_size, M, C) float32 — ADC outputs per row-block (each row-block
      is a physically separate crossbar, so partial sums are digitized
      *before* being accumulated digitally).
    """
    m, k = x_plane.shape
    k2, c = w_bits.shape
    assert k == k2, f"inner dims differ: {k} vs {k2}"
    blocks = -(-k // pe_size)
    pad = blocks * pe_size - k
    if pad:
        x_plane = jnp.pad(x_plane, ((0, 0), (0, pad)))
        w_bits = jnp.pad(w_bits, ((0, pad), (0, 0)))

    deltas = column_deltas(w_bits, pe_size, adc_bits)
    kernel = partial(_crossbar_kernel, levels=adc_levels(adc_bits))
    return pl.pallas_call(
        kernel,
        grid=(blocks,),
        in_specs=[
            pl.BlockSpec((m, pe_size), lambda b: (0, b)),
            pl.BlockSpec((pe_size, c), lambda b: (b, 0)),
            pl.BlockSpec((1, c), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, m, c), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, m, c), jnp.float32),
        interpret=interpret,
    )(x_plane, w_bits, deltas)


def weight_to_bits(w_q, n_bits=DEFAULT_N_BITS):
    """Bit-slice integer weights (two's complement) into 0/1 columns.

    Args:
      w_q: (K, N) int32 in [-2^(n-1), 2^(n-1)-1].
    Returns:
      (K, N * n_bits) float32 of {0, 1}; column n*n_bits-major: bit j of
      weight column n lives at flat column n * n_bits + j.
    """
    w_u = jnp.asarray(w_q, jnp.int32) & ((1 << n_bits) - 1)  # two's complement
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    bits = (w_u[:, :, None] >> shifts[None, None, :]) & 1
    k, n, _ = bits.shape
    return bits.astype(jnp.float32).reshape(k, n * n_bits)


def activation_to_planes(x_q, n_bits=DEFAULT_N_BITS):
    """Split unsigned integer activations into bit-planes.

    Args:
      x_q: (M, K) int32 in [0, 2^n - 1].
    Returns:
      (n_bits, M, K) float32 of {0, 1}, LSB first.
    """
    shifts = jnp.arange(n_bits, dtype=jnp.int32)
    planes = (jnp.asarray(x_q, jnp.int32)[None] >> shifts[:, None, None]) & 1
    return planes.astype(jnp.float32)


def bit_weights(n_bits: int):
    """Shift-and-add weights per weight bit (two's complement: MSB < 0)."""
    w = jnp.float32(2.0) ** jnp.arange(n_bits, dtype=jnp.float32)
    return w.at[n_bits - 1].set(-w[n_bits - 1])


def imc_matmul(x_q, w_q, *, pe_size=DEFAULT_PE, n_bits=DEFAULT_N_BITS,
               adc_bits=DEFAULT_ADC_BITS, interpret=True):
    """Full IMC matrix multiply: y = x_q @ w_q under crossbar semantics.

    Args:
      x_q: (M, K) int32, unsigned activations in [0, 2^n_bits - 1].
      w_q: (K, N) int32, signed weights in [-2^(n_bits-1), 2^(n_bits-1)-1].
    Returns:
      (M, N) float32 — the hardware-quantized product (exact when every
      bitline count is representable by the ADC, else ADC-rounded).
    """
    m, k = x_q.shape
    _, n = w_q.shape
    w_bits = weight_to_bits(w_q, n_bits)
    planes = activation_to_planes(x_q, n_bits)
    wb = bit_weights(n_bits)

    # The bit-plane loop is unrolled (n_bits is static and small) — this is
    # also the hardware truth: planes are sequential reads in time. NOTE:
    # lax.map/vmap over pallas_call mis-batches the grid index maps in
    # interpret mode, so the unroll is load-bearing, not just stylistic.
    out = jnp.zeros((m, n), jnp.float32)
    for b in range(n_bits):
        # (blocks, M, N*n_bits) ADC outputs for this input bit-plane.
        q = crossbar_read(planes[b], w_bits, pe_size=pe_size,
                          adc_bits=adc_bits, interpret=interpret)
        # Digital accumulate over crossbars, then weight-bit shift-add.
        q = q.sum(axis=0).reshape(m, n, n_bits)
        out = out + jnp.float32(2.0) ** b * jnp.einsum("mnb,b->mn", q, wb)
    return out
