"""Pure-jnp correctness oracle for the Pallas IMC crossbar kernel.

Implements exactly the same bit-serial / bit-sliced / ADC-quantized
dataflow as ``imc_crossbar.py`` but with plain ``jnp`` ops (no pallas, no
blocking) so the two can be compared bit-exactly, plus the *ideal*
(infinite-ADC) integer matmul used to bound quantization error.
"""

import jax.numpy as jnp

from . import imc_crossbar as k


def crossbar_read_ref(x_plane, w_bits, *, pe_size=k.DEFAULT_PE,
                      adc_bits=k.DEFAULT_ADC_BITS):
    """Reference for ``imc_crossbar.crossbar_read`` (unblocked jnp)."""
    m, kk = x_plane.shape
    blocks = -(-kk // pe_size)
    pad = blocks * pe_size - kk
    if pad:
        x_plane = jnp.pad(x_plane, ((0, 0), (0, pad)))
        w_bits = jnp.pad(w_bits, ((0, pad), (0, 0)))
    levels = k.adc_levels(adc_bits)
    delta = k.column_deltas(w_bits, pe_size, adc_bits)[:, None, :]
    xs = x_plane.reshape(m, blocks, pe_size).transpose(1, 0, 2)
    ws = w_bits.reshape(blocks, pe_size, -1)
    s = jnp.einsum("bmk,bkc->bmc", xs, ws)
    return jnp.clip(jnp.round(s / delta), 0.0, float(levels)) * delta


def imc_matmul_ref(x_q, w_q, *, pe_size=k.DEFAULT_PE, n_bits=k.DEFAULT_N_BITS,
                   adc_bits=k.DEFAULT_ADC_BITS):
    """Reference for ``imc_crossbar.imc_matmul``."""
    m, _ = x_q.shape
    _, n = w_q.shape
    w_bits = k.weight_to_bits(w_q, n_bits)
    planes = k.activation_to_planes(x_q, n_bits)
    wb = k.bit_weights(n_bits)
    plane_w = jnp.float32(2.0) ** jnp.arange(n_bits, dtype=jnp.float32)
    out = jnp.zeros((m, n), jnp.float32)
    for b in range(n_bits):
        q = crossbar_read_ref(planes[b], w_bits, pe_size=pe_size,
                              adc_bits=adc_bits)
        q = q.sum(axis=0).reshape(m, n, n_bits)
        out = out + plane_w[b] * jnp.einsum("mnb,b->mn", q, wb)
    return out


def ideal_matmul(x_q, w_q):
    """Infinite-precision integer matmul (no ADC quantization)."""
    return jnp.asarray(x_q, jnp.float32) @ jnp.asarray(w_q, jnp.float32)


def adc_error_bound(k_dim, *, pe_size=k.DEFAULT_PE, n_bits=k.DEFAULT_N_BITS,
                    adc_bits=k.DEFAULT_ADC_BITS):
    """Worst-case |imc - ideal| for a K-deep dot product.

    Each ADC conversion errs by at most delta/2 (plus clipping, which the
    bound ignores — callers should keep bitline counts under full scale);
    there are blocks x n_bits x n_bits conversions contributing to one
    output, weighted by 2^i x (+/-2^j).
    """
    blocks = -(-k_dim // pe_size)
    delta = k.adc_delta(pe_size, adc_bits)
    weight_sum = float(sum(2.0 ** i for i in range(n_bits)) ** 2)
    return 0.5 * delta * blocks * weight_sum
