"""AOT compile path: lower the L2 forwards to HLO *text* artifacts.

HLO text — NOT serialized ``HloModuleProto`` — is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids that the rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.

Weights are baked into the artifact as constants (closure capture) so the
rust hot path only feeds activations — exactly the paper's §5 execution
model (weights loaded once, pre-execution).

Usage::

    python -m compile.aot --out ../artifacts          # all artifacts
    python -m compile.aot --model mlp --out path.txt  # one artifact
"""

import argparse
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

MLP_BATCH = 8
LENET_BATCH = 4


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    # print_large_constants=True is load-bearing: the default printer elides
    # big weight constants as `constant({...})`, which the rust-side text
    # parser would read back as zeros.
    return comp.as_hlo_text(print_large_constants=True)


def lower_mlp(batch=MLP_BATCH, seed=0):
    """Lower the IMC-quantized MLP at a fixed batch size."""
    params = model.init_mlp_params(seed=seed)
    leaves = model.params_q(params)

    def fn(x):
        return model.mlp_forward(leaves, x)

    spec = jax.ShapeDtypeStruct((batch, model.MLP_DIMS[0]), jnp.float32)
    return jax.jit(fn).lower(spec)


def lower_mlp_float(batch=MLP_BATCH, seed=0):
    """Float twin of the MLP (agreement baseline for the e2e example)."""
    params = model.init_mlp_params(seed=seed)
    ws = [p["w"] for p in params]

    def fn(x):
        h = x
        for i, w in enumerate(ws):
            h = h @ w
            if i != len(ws) - 1:
                h = jnp.maximum(h, 0.0)
                h = h / jnp.maximum(jnp.max(h), 1e-6)
        return (h,)

    spec = jax.ShapeDtypeStruct((batch, model.MLP_DIMS[0]), jnp.float32)
    return jax.jit(fn).lower(spec)


def lower_lenet(batch=LENET_BATCH, seed=1):
    """Lower the IMC-quantized LeNet at a fixed batch size."""
    params = model.init_lenet_params(seed=seed)
    leaves = model.lenet_params_q(params)

    def fn(x):
        return model.lenet_forward(leaves, x)

    spec = jax.ShapeDtypeStruct((batch, 784), jnp.float32)
    return jax.jit(fn).lower(spec)


ARTIFACTS = {
    "mlp": lower_mlp,
    "mlp_float": lower_mlp_float,
    "lenet": lower_lenet,
}


def build_all(out_dir: str) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, lower in ARTIFACTS.items():
        text = to_hlo_text(lower())
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {path}")
    # Default model alias used by the Makefile freshness check.
    src = os.path.join(out_dir, "mlp.hlo.txt")
    dst = os.path.join(out_dir, "model.hlo.txt")
    with open(src) as f, open(dst, "w") as g:
        g.write(f.read())
    print(f"aliased {dst}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", required=True,
                    help="output directory (or file with --model)")
    ap.add_argument("--model", choices=sorted(ARTIFACTS), default=None,
                    help="lower a single model to --out")
    args = ap.parse_args()
    if args.model:
        text = to_hlo_text(ARTIFACTS[args.model]())
        os.makedirs(os.path.dirname(os.path.abspath(args.out)), exist_ok=True)
        with open(args.out, "w") as f:
            f.write(text)
        print(f"wrote {len(text)} chars to {args.out}")
    else:
        out_dir = args.out
        if out_dir.endswith(".hlo.txt"):
            out_dir = os.path.dirname(out_dir)
        build_all(out_dir or ".")


if __name__ == "__main__":
    main()
