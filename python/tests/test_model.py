"""L2 correctness: model shapes, quantization, IMC-vs-float agreement."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import imc_crossbar as xbar


def test_mlp_shapes_and_determinism():
    params = model.init_mlp_params(seed=0)
    leaves = model.params_q(params)
    x = jax.random.uniform(jax.random.PRNGKey(1), (8, 784))
    y1 = model.mlp_forward(leaves, x)[0]
    y2 = model.mlp_forward(leaves, x)[0]
    assert y1.shape == (8, 10)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


def test_mlp_agreement_with_float():
    """IMC-quantized argmax agrees with float on most synthetic inputs."""
    params = model.init_mlp_params(seed=0)
    leaves = model.params_q(params)
    x = jax.random.uniform(jax.random.PRNGKey(2), (32, 784))
    yq = model.mlp_forward(leaves, x)[0]
    yf = model.mlp_forward_float(params, x)[0]
    agree = float(jnp.mean((jnp.argmax(yq, 1) == jnp.argmax(yf, 1))
                           .astype(jnp.float32)))
    assert agree >= 0.6, f"IMC/float argmax agreement {agree}"


def test_lenet_shapes_and_agreement():
    params = model.init_lenet_params(seed=1)
    leaves = model.lenet_params_q(params)
    x = jax.random.uniform(jax.random.PRNGKey(3), (4, 784))
    yq = model.lenet_forward(leaves, x)[0]
    yf = model.lenet_forward_float(params, x)[0]
    assert yq.shape == (4, 10)
    agree = float(jnp.mean((jnp.argmax(yq, 1) == jnp.argmax(yf, 1))
                           .astype(jnp.float32)))
    assert agree >= 0.5, f"LeNet agreement {agree}"


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000))
def test_quantize_roundtrip_weights(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (37, 11)) * 0.3
    w_q, scale = model.quantize_weights(w, 8)
    rec = np.asarray(w_q, np.float32) * float(scale)
    err = np.abs(rec - np.asarray(w)).max()
    assert err <= float(scale) / 2 + 1e-6


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 1000), n_bits=st.sampled_from([4, 8]))
def test_quantize_activations_range(seed, n_bits):
    x = jax.random.uniform(jax.random.PRNGKey(seed), (5, 17))
    x_q = model.quantize_activations(x, n_bits)
    assert int(x_q.min()) >= 0
    assert int(x_q.max()) <= (1 << n_bits) - 1
    # Monotone in x.
    order = jnp.argsort(x[0])
    assert bool(jnp.all(jnp.diff(x_q[0][order]) >= 0))


def test_im2col_matches_conv():
    """im2col + matmul equals lax.conv with 'same' padding."""
    key = jax.random.PRNGKey(4)
    x = jax.random.uniform(key, (2, 8, 8, 3))
    w = jax.random.normal(jax.random.PRNGKey(5), (5, 5, 3, 6)) * 0.1
    cols = model._im2col(x, 5)  # (2*8*8, 75) in (k,k,C) order
    y_cols = (cols @ w.reshape(75, 6)).reshape(2, 8, 8, 6)
    y_conv = jax.lax.conv_general_dilated(
        x, w, (1, 1), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y_cols), np.asarray(y_conv),
                               rtol=1e-4, atol=1e-4)


def test_imc_linear_scales_back_to_real_units():
    """imc_linear approximates the real-valued product."""
    key = jax.random.PRNGKey(6)
    x = jax.random.uniform(key, (4, 100))
    w = jax.random.normal(jax.random.PRNGKey(7), (100, 5)) * 0.1
    w_q, scale = model.quantize_weights(w, 8)
    y_imc = model.imc_linear(x, w_q, scale, pe_size=64)
    y_real = x @ w
    # Relative tolerance is generous: 4-bit ADC + 8-bit codes.
    err = float(jnp.max(jnp.abs(y_imc - y_real)))
    ref_mag = float(jnp.max(jnp.abs(y_real)))
    assert err <= 0.35 * max(ref_mag, 1e-3), f"err {err} vs mag {ref_mag}"


@pytest.mark.parametrize("dims", [(20, 12, 6), (300, 64, 10)])
def test_mlp_forward_custom_dims(dims):
    full_dims = dims
    params = model.init_mlp_params(seed=9, dims=full_dims)
    leaves = model.params_q(params)
    x = jax.random.uniform(jax.random.PRNGKey(8), (3, dims[0]))
    y = model.mlp_forward(leaves, x)[0]
    assert y.shape == (3, dims[-1])


def test_bit_weights_msb_negative():
    wb = np.asarray(xbar.bit_weights(8))
    assert wb[-1] == -128.0
    assert (wb[:-1] > 0).all()
