"""L1 correctness: Pallas kernel vs pure-jnp oracle (the core signal).

hypothesis sweeps shapes / bit-widths / PE sizes; every case must match the
oracle exactly (identical float ops on {0,1} data), and the quantized
product must stay within the analytic ADC error bound of the ideal
integer matmul.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import imc_crossbar as xbar
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def _rand_case(seed, m, k, n, n_bits):
    kx, kw = jax.random.split(jax.random.PRNGKey(seed))
    x_q = jax.random.randint(kx, (m, k), 0, 1 << n_bits)
    lo = -(1 << (n_bits - 1))
    hi = (1 << (n_bits - 1))
    w_q = jax.random.randint(kw, (k, n), lo, hi)
    return x_q, w_q


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 6),
    k=st.integers(1, 200),
    n=st.integers(1, 8),
    n_bits=st.sampled_from([2, 4, 8]),
    adc_bits=st.sampled_from([2, 4, 6]),
    pe_size=st.sampled_from([32, 64, 128]),
)
def test_kernel_matches_ref(seed, m, k, n, n_bits, adc_bits, pe_size):
    x_q, w_q = _rand_case(seed, m, k, n, n_bits)
    got = xbar.imc_matmul(x_q, w_q, pe_size=pe_size, n_bits=n_bits,
                          adc_bits=adc_bits)
    want = ref.imc_matmul_ref(x_q, w_q, pe_size=pe_size, n_bits=n_bits,
                              adc_bits=adc_bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=0, atol=1e-4)


@settings(**SETTINGS)
@given(
    seed=st.integers(0, 2**31 - 1),
    m=st.integers(1, 4),
    k=st.integers(1, 150),
    n=st.integers(1, 6),
    n_bits=st.sampled_from([2, 4]),
)
def test_quantization_error_bounded(seed, m, k, n, n_bits):
    x_q, w_q = _rand_case(seed, m, k, n, n_bits)
    got = xbar.imc_matmul(x_q, w_q, pe_size=64, n_bits=n_bits, adc_bits=4)
    ideal = ref.ideal_matmul(x_q, w_q)
    bound = ref.adc_error_bound(k, pe_size=64, n_bits=n_bits, adc_bits=4)
    err = float(jnp.max(jnp.abs(got - ideal)))
    assert err <= bound + 1e-3, f"error {err} exceeds bound {bound}"


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1), k=st.integers(1, 120))
def test_exact_when_adc_wide_enough(seed, k):
    """With enough ADC codes to represent every count, IMC == ideal."""
    x_q, w_q = _rand_case(seed, 3, k, 4, 2)
    # 8-bit ADC on <=64-row blocks: delta = 1 -> lossless.
    got = xbar.imc_matmul(x_q, w_q, pe_size=64, n_bits=2, adc_bits=8)
    ideal = ref.ideal_matmul(x_q, w_q)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ideal),
                               rtol=0, atol=1e-3)


def test_weight_bits_roundtrip():
    """Bit-slicing + two's-complement shift-add reconstructs the weights."""
    w_q = jnp.arange(-8, 8, dtype=jnp.int32).reshape(16, 1)
    bits = xbar.weight_to_bits(w_q, 4).reshape(16, 1, 4)
    wb = xbar.bit_weights(4)
    rec = jnp.einsum("knb,b->kn", bits, wb)
    np.testing.assert_array_equal(np.asarray(rec).ravel(),
                                  np.arange(-8, 8, dtype=np.float32))


def test_activation_planes_roundtrip():
    x_q = jnp.arange(0, 16, dtype=jnp.int32).reshape(4, 4)
    planes = xbar.activation_to_planes(x_q, 4)
    weights = 2.0 ** np.arange(4)
    rec = np.einsum("bmk,b->mk", np.asarray(planes), weights)
    np.testing.assert_array_equal(rec, np.asarray(x_q, dtype=np.float32))


def test_adc_monotone():
    """The ADC transfer function is monotone in the bitline count."""
    w_bits = jnp.ones((64, 3), jnp.float32)
    prev = -1.0
    for ones in range(0, 65, 8):
        x = jnp.zeros((1, 64), jnp.float32).at[0, :ones].set(1.0)
        q = float(ref.crossbar_read_ref(x, w_bits, pe_size=64, adc_bits=4)[0, 0, 0])
        assert q >= prev
        prev = q


def test_k_padding_is_transparent():
    """K not a multiple of pe_size pads with zero rows (no value change)."""
    x_q, w_q = _rand_case(7, 2, 65, 3, 4)
    a = xbar.imc_matmul(x_q, w_q, pe_size=64, n_bits=4, adc_bits=4)
    # Explicitly pad K to 128 with zeros: same result.
    x_pad = jnp.pad(x_q, ((0, 0), (0, 63)))
    w_pad = jnp.pad(w_q, ((0, 63), (0, 0)))
    b = xbar.imc_matmul(x_pad, w_pad, pe_size=64, n_bits=4, adc_bits=4)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


@pytest.mark.parametrize("adc_bits", [2, 4, 8])
def test_more_adc_bits_never_hurt(adc_bits):
    x_q, w_q = _rand_case(3, 4, 128, 4, 4)
    ideal = np.asarray(ref.ideal_matmul(x_q, w_q))
    got = np.asarray(
        xbar.imc_matmul(x_q, w_q, pe_size=64, n_bits=4, adc_bits=adc_bits)
    )
    err = np.abs(got - ideal).max()
    got_hi = np.asarray(
        xbar.imc_matmul(x_q, w_q, pe_size=64, n_bits=4, adc_bits=adc_bits + 2)
    )
    err_hi = np.abs(got_hi - ideal).max()
    assert err_hi <= err + 1e-4
