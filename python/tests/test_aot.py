"""AOT path: HLO text emission, shape/parameter sanity, float-twin parity.

The rust round trip itself is covered by `rust/tests/runtime_e2e.rs`; here
we check the compile path emits parseable single-module HLO text with the
expected entry signature.
"""

import re

import jax.numpy as jnp
import numpy as np

from compile import aot, model


def _entry_shapes(hlo_text):
    """Extract the ENTRY computation's parameter shapes and ROOT line."""
    entry = hlo_text[hlo_text.index("ENTRY "):]
    body = entry[: entry.index("\n}")]
    params = re.findall(r"(\S+\[[\d,]*\])\S*\s+parameter\(\d+\)", body)
    root = [l for l in body.splitlines() if l.strip().startswith("ROOT")]
    assert root, "no ROOT in ENTRY"
    return params, root[0]


def test_mlp_artifact_text():
    text = aot.to_hlo_text(aot.lower_mlp(batch=2))
    assert text.startswith("HloModule"), text[:60]
    params, root = _entry_shapes(text)
    # Weights are baked in as constants: exactly one (activation) parameter.
    assert params == ["f32[2,784]"]
    assert "f32[2,10]" in root


def test_lenet_artifact_text():
    text = aot.to_hlo_text(aot.lower_lenet(batch=1))
    assert text.startswith("HloModule")
    params, root = _entry_shapes(text)
    assert params == ["f32[1,784]"]
    assert "f32[1,10]" in root


def test_float_twin_matches_eager():
    """The lowered float MLP equals the eager float forward."""
    lowered = aot.lower_mlp_float(batch=2, seed=0)
    compiled = lowered.compile()
    params = model.init_mlp_params(seed=0)
    x = jnp.linspace(0.0, 1.0, 2 * 784, dtype=jnp.float32).reshape(2, 784)
    got = compiled(x)[0]
    want = model.mlp_forward_float(params, x)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_quantized_artifact_matches_eager():
    """The lowered IMC MLP equals the eager IMC forward (same weights)."""
    lowered = aot.lower_mlp(batch=2, seed=0)
    compiled = lowered.compile()
    params = model.init_mlp_params(seed=0)
    leaves = model.params_q(params)
    x = jnp.linspace(0.0, 1.0, 2 * 784, dtype=jnp.float32).reshape(2, 784)
    got = compiled(x)[0]
    want = model.mlp_forward(leaves, x)[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-4)


def test_artifact_registry_complete():
    assert set(aot.ARTIFACTS) == {"mlp", "mlp_float", "lenet"}
