//! Quickstart: evaluate one DNN on the heterogeneous-interconnect IMC
//! architecture and print the paper's headline metrics.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use imcnoc::arch::{CommBackend, HeteroArchitecture};
use imcnoc::config::ArchConfig;
use imcnoc::dnn::models;

fn main() {
    // 1. Pick a workload from the model zoo.
    let vgg = models::vgg(19);
    let report = vgg.density_report();
    println!(
        "{}: {} neurons, connection density {:.0}",
        vgg.name,
        report.neurons,
        report.connection_density()
    );

    // 2. Build the proposed architecture (ReRAM tiles, Table 2 defaults)
    //    and let the advisor choose the tile-level NoC (Fig. 20 rule).
    let hw = HeteroArchitecture::new(ArchConfig::reram());
    let eval = hw.evaluate(&vgg, CommBackend::Analytical);

    // 3. Report what Table 4 reports.
    println!("chosen interconnect : {}", eval.topology.name());
    println!("tiles / crossbars   : {} / {}", eval.tiles, eval.crossbars);
    println!("latency             : {:.3} ms", eval.latency_s() * 1e3);
    println!("  compute           : {:.3} ms", eval.compute_latency_s * 1e3);
    println!("  exposed routing   : {:.3} ms", eval.comm_latency_s * 1e3);
    println!("power / frame       : {:.3} W", eval.power_w());
    println!("area                : {:.1} mm2", eval.area_mm2());
    println!("throughput          : {:.0} FPS", eval.fps());
    println!("EDAP                : {:.3} J.ms.mm2", eval.edap());
}
