//! Cloud scale-up study: high-connection-density DNNs on ReRAM IMC,
//! comparing fixed NoC-tree vs NoC-mesh vs the P2P baseline — the paper's
//! core message that interconnect choice dominates at high density.
//!
//! ```sh
//! cargo run --release --example cloud_scaleup
//! ```

use imcnoc::arch::{CommBackend, HeteroArchitecture};
use imcnoc::config::ArchConfig;
use imcnoc::dnn::models;
use imcnoc::noc::topology::Topology;
use imcnoc::util::Table;

fn main() {
    let dense_models = [models::resnet(50), models::vgg(19), models::densenet(100)];
    let hw = HeteroArchitecture::new(ArchConfig::reram());

    let mut t = Table::new(
        "Cloud scale-up (ReRAM IMC): FPS by interconnect",
        &["dnn", "P2P", "NoC-tree", "NoC-mesh", "mesh/P2P"],
    );
    for g in &dense_models {
        let fps: Vec<f64> = [Topology::P2P, Topology::Tree, Topology::Mesh]
            .into_iter()
            .map(|topo| hw.evaluate_with(g, topo, CommBackend::Analytical).fps())
            .collect();
        t.add_row(vec![
            g.name.clone(),
            format!("{:.1}", fps[0]),
            format!("{:.1}", fps[1]),
            format!("{:.1}", fps[2]),
            format!("{:.2}x", fps[2] / fps[0]),
        ]);
        assert!(
            fps[2] >= fps[0],
            "{}: mesh must not lose to P2P at high density",
            g.name
        );
    }
    print!("{}", t.render());
    println!("\nNoC-based interconnects sustain dense DNNs where P2P collapses (paper Fig. 8/21).");
}
