//! Chiplet scale-out: shard a large DNN across a 2.5D package of IMC
//! chiplets and compare package-level (NoP) topologies.
//!
//! ```sh
//! cargo run --release --example chiplet_scaleout
//! ```

use imcnoc::arch::{recommend_scaleout, recommend_topology, CommBackend};
use imcnoc::config::{ArchConfig, NocConfig, NopConfig, SimConfig};
use imcnoc::dnn::models;
use imcnoc::nop::{evaluate_package, NopTopology};

fn main() {
    // 1. A package-scale workload: VGG-19 needs hundreds of tiles — more
    //    than a single reticle-friendly chiplet comfortably holds.
    let vgg = models::vgg(19);
    let arch = ArchConfig::reram();
    let base_noc = NocConfig::default();

    // 2. Per-chiplet NoC chosen by the paper's single-chip advisor.
    let noc_topo = recommend_topology(&vgg, &arch, &base_noc).topology;
    let noc = NocConfig {
        topology: noc_topo,
        ..base_noc.clone()
    };
    println!("{}: per-chiplet NoC = {}", vgg.name, noc_topo.name());

    // 3. Evaluate a 4-chiplet package under each NoP topology.
    for nop_topo in NopTopology::all() {
        let nop = NopConfig {
            topology: nop_topo,
            chiplets: 4,
            ..NopConfig::default()
        };
        let e = evaluate_package(
            &vgg,
            &arch,
            &noc,
            &nop,
            &SimConfig::default(),
            CommBackend::Analytical,
        );
        println!(
            "NoP {:>5}: latency {:.3} ms  energy {:.3} mJ  area {:.1} mm2  EDAP {:.3}  ({} kbit/frame cross-chiplet)",
            nop_topo.name(),
            e.latency_s() * 1e3,
            e.energy_j() * 1e3,
            e.area_mm2(),
            e.edap(),
            e.cross_bits / 1000,
        );
    }

    // 4. The joint advisor searches (chiplets x NoP x NoC) by EDAP.
    let rec = recommend_scaleout(&vgg, &arch, &base_noc, &NopConfig::default());
    println!(
        "joint recommendation: {} chiplet(s), NoP {}, per-chiplet {} (EDAP {:.3})",
        rec.chiplets,
        rec.nop_topology.name(),
        rec.noc_topology.name(),
        rec.best.edap(),
    );
}
