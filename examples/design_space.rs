//! Design-space exploration: the paper's §5.2 crossbar-size study — sweep
//! PE sizes 64..512 over a sample of DNNs and find the size that minimizes
//! EDAP most often (the paper finds 256×256 wins for 75% of its sample).
//!
//! ```sh
//! cargo run --release --example design_space
//! ```

use imcnoc::arch::{evaluate, recommend_topology, CommBackend};
use imcnoc::config::{ArchConfig, NocConfig, SimConfig};
use imcnoc::dnn::models;
use imcnoc::util::Table;

fn main() {
    // The paper's §5.2 sample (8 DNNs).
    let sample = [
        models::lenet5(),
        models::nin(),
        models::squeezenet(),
        models::resnet(152),
        models::resnet(50),
        models::vgg(16),
        models::vgg(19),
        models::densenet(100),
    ];
    let pe_sizes = [64usize, 128, 256, 512];
    let sim = SimConfig::default();

    let mut t = Table::new(
        "Crossbar-size DSE (ReRAM, advisor topology): EDAP by PE size",
        &["dnn", "64", "128", "256", "512", "best"],
    );
    let mut wins = vec![0usize; pe_sizes.len()];
    for g in &sample {
        let mut row = vec![g.name.clone()];
        let mut edaps = Vec::new();
        for &pe in &pe_sizes {
            let arch = ArchConfig {
                pe_size: pe,
                ..ArchConfig::reram()
            };
            let rec = recommend_topology(g, &arch, &NocConfig::default());
            let e = evaluate(
                g,
                rec.topology,
                &arch,
                &NocConfig::with_topology(rec.topology),
                &sim,
                CommBackend::Analytical,
            );
            edaps.push(e.edap());
            row.push(format!("{:.4}", e.edap()));
        }
        let best = edaps
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .unwrap()
            .0;
        wins[best] += 1;
        row.push(pe_sizes[best].to_string());
        t.add_row(row);
    }
    print!("{}", t.render());
    for (pe, w) in pe_sizes.iter().zip(&wins) {
        println!("PE {pe:>3}: best for {w}/{} DNNs", sample.len());
    }
    println!(
        "\nPaper §5.2: 256x256 minimizes EDAP for ~75% of the sample; our \
         model reports the distribution above."
    );
}
