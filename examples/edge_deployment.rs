//! Edge deployment study: compact DNNs (the paper's low-connection-density
//! group) on SRAM IMC with the topology advisor — the scenario the paper's
//! intro motivates for edge hardware (low power, NoC-tree region).
//!
//! ```sh
//! cargo run --release --example edge_deployment
//! ```

use imcnoc::arch::{recommend_topology, CommBackend, HeteroArchitecture};
use imcnoc::config::{ArchConfig, NocConfig};
use imcnoc::dnn::models;
use imcnoc::util::Table;

fn main() {
    let edge_models = [models::mlp(), models::lenet5(), models::nin(), models::squeezenet()];
    let hw = HeteroArchitecture::new(ArchConfig::sram());

    let mut t = Table::new(
        "Edge deployment (SRAM IMC, advisor-chosen interconnect)",
        &[
            "dnn", "density", "topology", "latency_ms", "power_W", "area_mm2",
            "FPS", "EDAP",
        ],
    );
    for g in &edge_models {
        let rec = recommend_topology(g, &hw.arch, &NocConfig::default());
        let e = hw.evaluate(g, CommBackend::Analytical);
        t.add_row(vec![
            g.name.clone(),
            format!("{:.0}", rec.density),
            e.topology.name().into(),
            format!("{:.4}", e.latency_s() * 1e3),
            format!("{:.3}", e.power_w()),
            format!("{:.2}", e.area_mm2()),
            format!("{:.0}", e.fps()),
            format!("{:.5}", e.edap()),
        ]);
    }
    print!("{}", t.render());

    // Edge sanity: every compact model must be advised NoC-tree (Fig. 20).
    for g in &edge_models {
        let rec = recommend_topology(g, &hw.arch, &NocConfig::default());
        if g.density_report().connection_density() < 1.0e3 {
            assert_eq!(rec.topology.name(), "NoC-tree", "{}", g.name);
        }
    }
    println!("\nAll compact models land in the NoC-tree region, as in the paper.");
}
