//! End-to-end driver (DESIGN.md §6): proves all three layers compose.
//!
//! 1. Loads the AOT-compiled IMC-quantized MLP artifact (L1 Pallas crossbar
//!    kernel inside an L2 JAX forward, lowered to HLO text by
//!    `make artifacts`) plus its float twin.
//! 2. Serves a few hundred batched inference requests through the rust
//!    coordinator via PJRT (no Python anywhere on this path), measuring
//!    real latency/throughput.
//! 3. Checks classification agreement between the hardware-quantized and
//!    float paths on the synthetic workload.
//! 4. Reports what the modeled ReRAM IMC chip (with the advisor-chosen
//!    NoC) would deliver for the same network.
//!
//! ```sh
//! make artifacts && cargo run --release --example e2e_inference
//! ```

use imcnoc::arch::{CommBackend, HeteroArchitecture};
use imcnoc::config::ArchConfig;
use imcnoc::coordinator::server::{argmax, synthetic_requests, InferenceServer};
use imcnoc::dnn::models;
use imcnoc::runtime::artifact_path;

const REQUESTS: usize = 256;
const BATCH: usize = 8; // must match the AOT batch (aot.py MLP_BATCH)
const IN_DIM: usize = 784;

fn main() -> anyhow::Result<()> {
    let imc_path = artifact_path("mlp");
    let float_path = artifact_path("mlp_float");
    if !imc_path.exists() || !float_path.exists() {
        eprintln!("artifacts missing — run `make artifacts` first");
        std::process::exit(2);
    }

    let mut server = InferenceServer::new(BATCH)?;
    println!("PJRT platform: {}", server.platform());

    let requests = synthetic_requests(REQUESTS, IN_DIM, 42);

    // --- Serve the IMC-quantized model (the hot path). ---
    let imc = server.serve(&imc_path, &requests, IN_DIM)?;
    println!(
        "IMC-quantized MLP : {} reqs, {:.2} ms/batch (p50 {:.2}, p99 {:.2}), {:.1} req/s",
        imc.requests, imc.mean_ms, imc.p50_ms, imc.p99_ms, imc.throughput_rps
    );

    // --- Serve the float twin and compare classifications. ---
    let flt = server.serve(&float_path, &requests, IN_DIM)?;
    println!(
        "float MLP         : {:.2} ms/batch, {:.1} req/s",
        flt.mean_ms, flt.throughput_rps
    );
    let agree = imc
        .outputs
        .iter()
        .zip(&flt.outputs)
        .filter(|(a, b)| argmax(a) == argmax(b))
        .count();
    let frac = agree as f64 / imc.outputs.len() as f64;
    println!(
        "classification agreement (4-bit-ADC IMC vs float): {agree}/{} = {:.1}%",
        imc.outputs.len(),
        100.0 * frac
    );
    assert!(
        frac > 0.5,
        "quantized/float agreement {frac} collapsed — kernel or AOT regression"
    );

    // --- What the modeled IMC silicon would deliver for this network. ---
    let mlp = models::mlp();
    let hw = HeteroArchitecture::new(ArchConfig::reram());
    let eval = hw.evaluate(&mlp, CommBackend::Analytical);
    println!(
        "\nmodeled ReRAM IMC chip for {} ({}): {:.0} FPS, {:.3} W, {:.2} mm2, EDAP {:.5}",
        mlp.name,
        eval.topology.name(),
        eval.fps(),
        eval.power_w(),
        eval.area_mm2(),
        eval.edap()
    );
    println!("e2e_inference OK");
    Ok(())
}
