#!/usr/bin/env python3
"""Windowed-metrics sanity gate for CI.

Usage: check_metrics.py METRICS.json

Validates a `repro serve --metrics-out` export (the JSON form): the
document must parse, windows must tile the run (t_s == index * window_s,
strictly increasing), every counter must be a non-negative integer, the
per-window model splits must sum to the window counters, the summed
windows must equal the cumulative `totals` block, and `totals` must
mirror the `report` block stamped from the `ServeReport` (arrivals ==
requests, completions == completed, drops == dropped, sheds == shed).
Quantiles must satisfy p99 >= p50 >= 0, link utilizations must be finite
and non-negative (a serialization burst recorded at its start time may
nudge one window past 1.0, so the per-window ceiling is 2.0), and drift
events must reference real windows/models with legal metric/direction
labels.
"""

import json
import math
import sys

DRIFT_METRICS = {"arrival_rate", "p99_ms"}
DRIFT_DIRECTIONS = {"up", "down"}
# Binned-at-start tolerance for a single window's link utilization.
UTIL_CEILING = 2.0


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def count(obj, key, where):
    v = obj.get(key)
    if not isinstance(v, int) or isinstance(v, bool) or v < 0:
        fail(f"{where}.{key} missing or not a count: {v!r}")
    return v


def num(obj, key, where):
    v = obj.get(key)
    if not isinstance(v, (int, float)) or isinstance(v, bool) or not math.isfinite(v):
        fail(f"{where}.{key} missing or not finite: {v!r}")
    return v


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            doc = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(doc, dict):
        fail("top level must be an object")
    window_s = num(doc, "window_s", "doc")
    if window_s <= 0:
        fail(f"window_s {window_s} must be positive")
    end_s = num(doc, "end_s", "doc")
    if end_s < 0:
        fail(f"end_s {end_s} must be non-negative")

    windows = doc.get("windows")
    if not isinstance(windows, list) or not windows:
        fail("windows must be a non-empty list")
    model_names = None
    sums = {"arrivals": 0, "completions": 0, "drops": 0, "sheds": 0}
    per_model = {}
    for wi, w in enumerate(windows):
        where = f"windows[{wi}]"
        if not isinstance(w, dict):
            fail(f"{where} is not an object")
        t_s = num(w, "t_s", where)
        if abs(t_s - wi * window_s) > 1e-6 * max(1.0, wi * window_s):
            fail(f"{where}.t_s {t_s} != {wi} * window_s {window_s}")
        for key in sums:
            sums[key] += count(w, key, where)
        p50 = num(w, "p50_ms", where)
        p99 = num(w, "p99_ms", where)
        if not 0 <= p50 <= p99:
            fail(f"{where}: p99 {p99} < p50 {p50} (or negative)")
        depth = w.get("queue_depth")
        if not isinstance(depth, dict):
            fail(f"{where}.queue_depth missing")
        d_mean = num(depth, "mean", f"{where}.queue_depth")
        d_max = num(depth, "max", f"{where}.queue_depth")
        if d_mean < 0 or d_max < 0 or d_mean > d_max + 1e-9:
            fail(f"{where}: queue depth mean {d_mean} / max {d_max}")
        models = w.get("models")
        if not isinstance(models, list):
            fail(f"{where}.models missing")
        names = [m.get("name") for m in models]
        if model_names is None:
            model_names = names
        elif names != model_names:
            fail(f"{where}: model order {names} != {model_names}")
        m_arr = m_comp = 0
        for m in models:
            mw = f"{where}.models[{m.get('name')!r}]"
            m_arr += count(m, "arrivals", mw)
            m_comp += count(m, "completions", mw)
            mp50 = num(m, "p50_ms", mw)
            mp99 = num(m, "p99_ms", mw)
            if not 0 <= mp50 <= mp99:
                fail(f"{mw}: p99 {mp99} < p50 {mp50} (or negative)")
            if num(m, "mean_ms", mw) < 0:
                fail(f"{mw}: negative mean")
            acc = per_model.setdefault(m["name"], [0, 0])
            acc[0] += m["arrivals"]
            acc[1] += m["completions"]
        if m_arr != w["arrivals"] or m_comp != w["completions"]:
            fail(
                f"{where}: model splits ({m_arr}, {m_comp}) != window"
                f" counters ({w['arrivals']}, {w['completions']})"
            )
        links = w.get("links")
        if not isinstance(links, list):
            fail(f"{where}.links missing")
        for li, link in enumerate(links):
            lw = f"{where}.links[{li}]"
            count(link, "src", lw)
            count(link, "dst", lw)
            util = num(link, "utilization", lw)
            if not 0 <= util <= UTIL_CEILING:
                fail(f"{lw}: utilization {util} outside [0, {UTIL_CEILING}]")

    totals = doc.get("totals")
    if not isinstance(totals, dict):
        fail("totals block missing")
    for key in sums:
        if count(totals, key, "totals") != sums[key]:
            fail(f"window sums {key} {sums[key]} != totals {totals[key]}")

    report = doc.get("report")
    if not isinstance(report, dict):
        fail("report block missing")
    pairs = [
        ("arrivals", "requests"),
        ("completions", "completed"),
        ("drops", "dropped"),
        ("sheds", "shed"),
    ]
    for t_key, r_key in pairs:
        if totals[t_key] != count(report, r_key, "report"):
            fail(f"totals.{t_key} {totals[t_key]} != report.{r_key} {report[r_key]}")

    drift = doc.get("drift_events")
    if not isinstance(drift, list):
        fail("drift_events must be a list")
    for di, d in enumerate(drift):
        dw = f"drift_events[{di}]"
        if count(d, "window", dw) >= len(windows):
            fail(f"{dw}: window {d['window']} out of range")
        if d.get("model") not in (model_names or []):
            fail(f"{dw}: unknown model {d.get('model')!r}")
        if d.get("metric") not in DRIFT_METRICS:
            fail(f"{dw}: illegal metric {d.get('metric')!r}")
        if d.get("direction") not in DRIFT_DIRECTIONS:
            fail(f"{dw}: illegal direction {d.get('direction')!r}")
        num(d, "value", dw)
        num(d, "baseline", dw)
        if num(d, "sigma", dw) < 0:
            fail(f"{dw}: negative sigma")

    print(
        f"OK: {len(windows)} windows reconcile with report"
        f" ({totals['arrivals']} == {report['requests']} requests,"
        f" {totals['completions']} completed, {totals['drops']} dropped,"
        f" {totals['sheds']} shed); {len(drift)} drift events"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
