#!/usr/bin/env python3
"""Critical-path blame-report gate for CI.

Usage: check_explain.py EXPLAIN.json

Validates a `repro serve … --explain-out` export (schema
`imcnoc-explain-v1`):

* well-formed JSON with the expected top-level keys and schema tag;
* request accounting is sane (completed <= requests, missed <= completed);
* every critical-path component total is finite and non-negative;
* each ranked link row carries non-negative components whose per-link
  serialization time fits inside the run horizon (a link cannot serialize
  critical-path payloads for longer than the run existed);
* link rows are sorted by critical-path ms (the "ranked" contract);
* per-model rows reconcile: sum of model requests == total requests, and
  each row's top_component names a known lifecycle phase;
* layer rows carry non-negative compute/comm and exposed <= comm.
"""

import json
import math
import sys

COMPONENTS = ("wait", "serialization", "propagation", "queue", "service")
TOP_COMPONENTS = {"wait", "serialization", "propagation", "queue", "service", "-"}


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def non_negative(obj, key, where):
    v = obj.get(key)
    if not isinstance(v, (int, float)) or not math.isfinite(v) or v < 0:
        fail(f"{where}.{key} must be a finite non-negative number, got {v!r}")
    return v


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            report = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(report, dict):
        fail("top level must be an object")
    if report.get("schema") != "imcnoc-explain-v1":
        fail(f"unexpected schema tag {report.get('schema')!r}")
    for key in ("links", "chiplets", "models", "layers"):
        if not isinstance(report.get(key), list):
            fail(f"missing or non-list {key!r} section")

    horizon = non_negative(report, "horizon_ms", "report")
    requests = report.get("requests")
    completed = report.get("completed")
    missed = report.get("missed")
    for name, v in (("requests", requests), ("completed", completed), ("missed", missed)):
        if not isinstance(v, int) or v < 0:
            fail(f"report.{name} must be a non-negative integer, got {v!r}")
    if completed > requests:
        fail(f"completed {completed} > requests {requests}")
    if missed > completed:
        fail(f"missed {missed} > completed {completed}")

    comps = report.get("components_ms")
    if not isinstance(comps, dict):
        fail("components_ms object missing")
    for c in COMPONENTS:
        non_negative(comps, c, "components_ms")

    prev_critical = None
    for i, link in enumerate(report["links"]):
        where = f"links[{i}]"
        if not isinstance(link.get("link"), str) or "-" not in link["link"]:
            fail(f"{where}.link must be a 'from-to' label, got {link.get('link')!r}")
        non_negative(link, "wait_ms", where)
        ser = non_negative(link, "serialization_ms", where)
        critical = non_negative(link, "critical_ms", where)
        for key in ("blocked_requests", "miss_count"):
            v = link.get(key)
            if not isinstance(v, int) or v < 0:
                fail(f"{where}.{key} must be a non-negative integer, got {v!r}")
        # A single link serializes critical-path payloads sequentially, so
        # its blamed serialization time cannot exceed the run horizon.
        if ser > horizon * (1 + 1e-9) + 1e-9:
            fail(f"{where} serialization {ser} ms exceeds horizon {horizon} ms")
        if prev_critical is not None and critical > prev_critical * (1 + 1e-9) + 1e-9:
            fail(f"{where} breaks the critical_ms ranking order")
        prev_critical = critical

    model_requests = 0
    for i, m in enumerate(report["models"]):
        where = f"models[{i}]"
        if not isinstance(m.get("model"), str) or not m["model"]:
            fail(f"{where}.model must be a non-empty string")
        for key in ("requests", "completed", "missed"):
            v = m.get(key)
            if not isinstance(v, int) or v < 0:
                fail(f"{where}.{key} must be a non-negative integer, got {v!r}")
        for key in ("ingress_ms", "queue_ms", "service_ms"):
            non_negative(m, key, where)
        if m.get("top_component") not in TOP_COMPONENTS:
            fail(f"{where}.top_component {m.get('top_component')!r} unknown")
        model_requests += m["requests"]
    if report["models"] and model_requests != requests:
        fail(f"per-model requests sum {model_requests} != total {requests}")

    for i, layer in enumerate(report["layers"]):
        where = f"layers[{i}]"
        non_negative(layer, "compute_ms", where)
        comm = non_negative(layer, "comm_ms", where)
        exposed = non_negative(layer, "exposed_ms", where)
        if exposed > comm * (1 + 1e-9) + 1e-9:
            fail(f"{where} exposed {exposed} ms exceeds comm {comm} ms")

    print(
        f"OK: schema imcnoc-explain-v1; {requests} requests"
        f" ({completed} completed, {missed} missed);"
        f" {len(report['links'])} ranked link(s) within horizon"
        f" {horizon:.3f} ms; {len(report['models'])} model row(s)"
        f" reconciled; {len(report['layers'])} layer row(s)"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
