#!/usr/bin/env python3
"""Surrogate fidelity gate for CI.

Usage: check_surrogate.py CHECK.json [MAX_ERR] [MIN_SPEEDUP]

CHECK.json is the dump written by `repro chiplet --surrogate-check-out`:
one record per (topology, k) config with the fitted curve's anchor
counts, fallback count, wall-clock for the full-sim and surrogate paths,
and the per-rate held-out comparison points.

The gate fails (exit 1) when any of these break:

  * a config has fewer than 2 surviving steady anchors (the fit is
    degenerate and would fall back everywhere);
  * the pooled held-out |rel_err| p50 or p99 exceeds MAX_ERR
    (default 0.05 — the "<= 5% error vs mode = sim" acceptance bound);
  * the aggregate wall-clock ratio sum(sim_ns) / sum(surrogate_ns)
    falls below MIN_SPEEDUP (default 5.0).

Malformed or unreadable input exits 2 so CI never passes on a broken
dump. Fallback holdout points (where the surrogate refused and the
consumer would have run the full simulator) are reported but excluded
from the error pool — they cost time, not accuracy.
"""

import json
import sys


def load_check(path):
    """Load the check JSON, failing the gate (exit 2) on a missing or
    malformed file instead of silently passing."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        print(f"ERROR: cannot read check file {path}: {e}")
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"ERROR: check file {path} is not valid JSON: {e}")
        sys.exit(2)
    configs = data.get("configs") if isinstance(data, dict) else None
    if not isinstance(configs, list) or not configs:
        print(f"ERROR: {path} must be an object with a non-empty 'configs' list")
        sys.exit(2)
    required = (
        "topology",
        "k",
        "sat_rate",
        "steady_anchors",
        "drain_anchors",
        "fallbacks",
        "sim_ns",
        "surrogate_ns",
        "holdout",
    )
    for c in configs:
        missing = [f for f in required if f not in c]
        if missing:
            print(f"ERROR: config record {c!r} is missing fields {missing}")
            sys.exit(2)
    return configs


def quantile(sorted_vals, q):
    """Nearest-rank quantile of an ascending list (q in [0, 1])."""
    if not sorted_vals:
        return 0.0
    idx = min(len(sorted_vals) - 1, int(q * len(sorted_vals)))
    return sorted_vals[idx]


def main() -> int:
    if len(sys.argv) < 2:
        print(__doc__)
        return 2
    configs = load_check(sys.argv[1])
    max_err = float(sys.argv[2]) if len(sys.argv) > 2 else 0.05
    min_speedup = float(sys.argv[3]) if len(sys.argv) > 3 else 5.0

    failures = []
    errs = []
    total_sim_ns = 0
    total_sur_ns = 0
    total_fallbacks = 0
    print(
        f"{'config':<12} {'sat_rate':>9} {'anchors':>8} {'holdout':>8}"
        f" {'fallback':>9} {'p50_err':>8} {'p99_err':>8} {'speedup':>8}"
    )
    for c in configs:
        name = f"{c['topology']}/k{c['k']}"
        if c["steady_anchors"] < 2:
            failures.append(f"{name}: only {c['steady_anchors']} steady anchors survived")
        pts = [h for h in c["holdout"] if h.get("rel_err") is not None]
        cfg_errs = sorted(abs(h["rel_err"]) for h in pts)
        errs.extend(cfg_errs)
        total_sim_ns += c["sim_ns"]
        total_sur_ns += c["surrogate_ns"]
        total_fallbacks += c["fallbacks"]
        speedup = c["sim_ns"] / max(c["surrogate_ns"], 1)
        print(
            f"{name:<12} {c['sat_rate']:>9.4f} {c['steady_anchors']:>8}"
            f" {len(pts):>8} {c['fallbacks']:>9}"
            f" {quantile(cfg_errs, 0.50):>8.4f} {quantile(cfg_errs, 0.99):>8.4f}"
            f" {speedup:>7.1f}x"
        )

    errs.sort()
    p50 = quantile(errs, 0.50)
    p99 = quantile(errs, 0.99)
    speedup = total_sim_ns / max(total_sur_ns, 1)
    print(
        f"\npooled over {len(configs)} configs, {len(errs)} held-out points,"
        f" {total_fallbacks} fallbacks:"
    )
    print(f"  |rel_err| p50 {p50:.4f}, p99 {p99:.4f} (budget {max_err:.2f})")
    print(
        f"  wall-clock sim {total_sim_ns / 1e6:.1f} ms vs surrogate"
        f" {total_sur_ns / 1e6:.1f} ms ({speedup:.1f}x, budget {min_speedup:.1f}x)"
    )

    if p50 > max_err:
        failures.append(f"pooled |rel_err| p50 {p50:.4f} exceeds {max_err:.2f}")
    if p99 > max_err:
        failures.append(f"pooled |rel_err| p99 {p99:.4f} exceeds {max_err:.2f}")
    if speedup < min_speedup:
        failures.append(f"speedup {speedup:.1f}x below required {min_speedup:.1f}x")

    if failures:
        print(f"\nFAIL: {len(failures)} surrogate gate(s) broken:")
        for f in failures:
            print(f"  {f}")
        return 1
    print("\nOK: surrogate within error budget and past the speedup bar")
    return 0


if __name__ == "__main__":
    sys.exit(main())
