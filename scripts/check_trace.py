#!/usr/bin/env python3
"""Chrome-trace sanity gate for CI.

Usage: check_trace.py TRACE.json

Validates a `repro serve --trace-out` export: the file must be valid JSON
in the Chrome trace object form, every event must carry a legal phase and
timestamps, at least one lifecycle slice must be present, and the span
population must reconcile exactly with the `ServeReport` totals stamped
into `otherData` (service slices == completed, dropped/shed instants ==
dropped/shed, and completed + dropped + shed == requests).

Counter tracks (ph "C", appended by the windowed time-series) are held to
their own contract: per counter name, timestamps are strictly increasing
and every args value is a non-negative number; the "serving totals" track
must be present with cumulative (non-decreasing) series whose final
values equal the otherData completed/dropped/shed totals.
"""

import json
import sys

LEGAL_PHASES = {"X", "i", "C", "M"}


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            trace = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(trace, dict):
        fail("top level must be the Chrome trace object form")
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")

    slices = []
    instants = {"dropped": 0, "shed": 0}
    counters = {}  # name -> list of (ts, args)
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(f"event {i} is not an object")
        ph = e.get("ph")
        if ph not in LEGAL_PHASES:
            fail(f"event {i} has illegal phase {ph!r}")
        if not isinstance(e.get("name"), str):
            fail(f"event {i} has no name")
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            fail(f"event {i} ({e['name']}) has no numeric ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"slice {i} ({e['name']}) has bad dur {dur!r}")
            slices.append(e)
        if ph == "i" and e["name"] in instants:
            instants[e["name"]] += 1
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                fail(f"counter {i} ({e['name']}) has no args object")
            for k, v in args.items():
                if not isinstance(v, (int, float)) or v < 0:
                    fail(f"counter {e['name']} arg {k} not a count: {v!r}")
            counters.setdefault(e["name"], []).append((e["ts"], args))

    if not slices:
        fail("no lifecycle slices (ph 'X') in the trace")

    for name, samples in counters.items():
        prev_ts = None
        for ts, _ in samples:
            if prev_ts is not None and ts <= prev_ts:
                fail(f"counter {name!r} ts not strictly increasing at {ts}")
            prev_ts = ts
    totals_track = counters.get("serving totals")
    if not totals_track:
        fail("no 'serving totals' counter track in the trace")
    prev = {}
    for ts, args in totals_track:
        for k, v in args.items():
            if v < prev.get(k, 0):
                fail(f"serving totals {k} not cumulative at ts {ts}")
            prev[k] = v

    other = trace.get("otherData")
    if not isinstance(other, dict):
        fail("otherData reconciliation object missing")
    totals = {}
    for key in ("completed", "dropped", "shed", "requests"):
        v = other.get(key)
        if not isinstance(v, int) or v < 0:
            fail(f"otherData.{key} missing or not a count: {v!r}")
        totals[key] = v
    if totals["completed"] + totals["dropped"] + totals["shed"] != totals["requests"]:
        fail(
            "span totals do not reconcile: "
            f"{totals['completed']} + {totals['dropped']} + {totals['shed']}"
            f" != {totals['requests']} requests"
        )
    services = sum(1 for e in slices if e["name"] == "service")
    if services != totals["completed"]:
        fail(f"{services} service slices != {totals['completed']} completed")
    for key in ("dropped", "shed"):
        if instants[key] != totals[key]:
            fail(f"{instants[key]} {key} instants != {totals[key]} reported")
    final = totals_track[-1][1]
    for key in ("completed", "dropped", "shed"):
        if final.get(key) != totals[key]:
            fail(
                f"serving totals final {key} {final.get(key)!r}"
                f" != otherData {totals[key]}"
            )

    print(
        f"OK: {len(events)} events, {len(slices)} slices,"
        f" {services} service spans == completed;"
        f" {totals['completed']}+{totals['dropped']}+{totals['shed']}"
        f" == {totals['requests']} requests;"
        f" {len(counters)} counter tracks reconciled"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
