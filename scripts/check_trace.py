#!/usr/bin/env python3
"""Chrome-trace sanity gate for CI.

Usage: check_trace.py TRACE.json

Validates a `repro serve --trace-out` export: the file must be valid JSON
in the Chrome trace object form, every event must carry a legal phase and
timestamps, at least one lifecycle slice must be present, and the span
population must reconcile exactly with the `ServeReport` totals stamped
into `otherData` (service slices == completed, dropped/shed instants ==
dropped/shed, and completed + dropped + shed == requests).

Counter tracks (ph "C", appended by the windowed time-series) are held to
their own contract: per counter name, timestamps are strictly increasing
and every args value is a non-negative number; the "serving totals" track
must be present with cumulative (non-decreasing) series whose final
values equal the otherData completed/dropped/shed totals.

Flow events (ph "s"/"f", the per-request causal arrows) must pair up —
every flow id carries exactly one start and one finish, no dangling ends —
and each end must be anchored inside an enclosing complete slice on the
same pid/tid (Perfetto silently drops unanchored flow ends).
"""

import json
import sys

LEGAL_PHASES = {"X", "i", "C", "M", "s", "f"}


def fail(msg):
    print(f"FAIL: {msg}")
    sys.exit(1)


def main() -> int:
    if len(sys.argv) != 2:
        print(__doc__)
        return 2
    path = sys.argv[1]
    try:
        with open(path) as f:
            trace = json.load(f)
    except OSError as e:
        fail(f"cannot read {path}: {e}")
    except json.JSONDecodeError as e:
        fail(f"{path} is not valid JSON: {e}")

    if not isinstance(trace, dict):
        fail("top level must be the Chrome trace object form")
    events = trace.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")

    slices = []
    instants = {"dropped": 0, "shed": 0}
    counters = {}  # name -> list of (ts, args)
    flow_starts = {}  # id -> list of events
    flow_finishes = {}  # id -> list of events
    for i, e in enumerate(events):
        if not isinstance(e, dict):
            fail(f"event {i} is not an object")
        ph = e.get("ph")
        if ph not in LEGAL_PHASES:
            fail(f"event {i} has illegal phase {ph!r}")
        if not isinstance(e.get("name"), str):
            fail(f"event {i} has no name")
        if ph != "M" and not isinstance(e.get("ts"), (int, float)):
            fail(f"event {i} ({e['name']}) has no numeric ts")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                fail(f"slice {i} ({e['name']}) has bad dur {dur!r}")
            slices.append(e)
        if ph == "i" and e["name"] in instants:
            instants[e["name"]] += 1
        if ph == "C":
            args = e.get("args")
            if not isinstance(args, dict) or not args:
                fail(f"counter {i} ({e['name']}) has no args object")
            for k, v in args.items():
                if not isinstance(v, (int, float)) or v < 0:
                    fail(f"counter {e['name']} arg {k} not a count: {v!r}")
            counters.setdefault(e["name"], []).append((e["ts"], args))
        if ph in ("s", "f"):
            fid = e.get("id")
            if not isinstance(fid, int) or fid < 0:
                fail(f"flow event {i} ({e['name']}) has bad id {fid!r}")
            side = flow_starts if ph == "s" else flow_finishes
            side.setdefault(fid, []).append(e)

    if not slices:
        fail("no lifecycle slices (ph 'X') in the trace")

    # Flow arrows: every id pairs one start with one finish, and each end
    # is anchored inside an enclosing slice on the same pid/tid.
    for fid, evs in flow_starts.items():
        if len(evs) != 1:
            fail(f"flow id {fid} has {len(evs)} starts (want 1)")
        if fid not in flow_finishes:
            fail(f"flow id {fid} has a start but no finish (dangling 's')")
    for fid, evs in flow_finishes.items():
        if len(evs) != 1:
            fail(f"flow id {fid} has {len(evs)} finishes (want 1)")
        if fid not in flow_starts:
            fail(f"flow id {fid} has a finish but no start (dangling 'f')")
    for fid in flow_starts:
        for e in (flow_starts[fid][0], flow_finishes[fid][0]):
            enclosed = any(
                s.get("pid") == e.get("pid")
                and s.get("tid") == e.get("tid")
                and s["ts"] <= e["ts"] <= s["ts"] + s["dur"]
                for s in slices
            )
            if not enclosed:
                fail(
                    f"flow id {fid} end (ph {e['ph']!r}) at ts {e['ts']}"
                    f" is not inside any slice on pid/tid"
                    f" {e.get('pid')}/{e.get('tid')}"
                )

    for name, samples in counters.items():
        prev_ts = None
        for ts, _ in samples:
            if prev_ts is not None and ts <= prev_ts:
                fail(f"counter {name!r} ts not strictly increasing at {ts}")
            prev_ts = ts
    totals_track = counters.get("serving totals")
    if not totals_track:
        fail("no 'serving totals' counter track in the trace")
    prev = {}
    for ts, args in totals_track:
        for k, v in args.items():
            if v < prev.get(k, 0):
                fail(f"serving totals {k} not cumulative at ts {ts}")
            prev[k] = v

    other = trace.get("otherData")
    if not isinstance(other, dict):
        fail("otherData reconciliation object missing")
    totals = {}
    for key in ("completed", "dropped", "shed", "requests"):
        v = other.get(key)
        if not isinstance(v, int) or v < 0:
            fail(f"otherData.{key} missing or not a count: {v!r}")
        totals[key] = v
    if totals["completed"] + totals["dropped"] + totals["shed"] != totals["requests"]:
        fail(
            "span totals do not reconcile: "
            f"{totals['completed']} + {totals['dropped']} + {totals['shed']}"
            f" != {totals['requests']} requests"
        )
    services = sum(1 for e in slices if e["name"] == "service")
    if services != totals["completed"]:
        fail(f"{services} service slices != {totals['completed']} completed")
    if flow_starts and len(flow_starts) != totals["completed"]:
        fail(
            f"{len(flow_starts)} flow arrows != {totals['completed']}"
            " completed requests"
        )
    for key in ("dropped", "shed"):
        if instants[key] != totals[key]:
            fail(f"{instants[key]} {key} instants != {totals[key]} reported")
    final = totals_track[-1][1]
    for key in ("completed", "dropped", "shed"):
        if final.get(key) != totals[key]:
            fail(
                f"serving totals final {key} {final.get(key)!r}"
                f" != otherData {totals[key]}"
            )

    print(
        f"OK: {len(events)} events, {len(slices)} slices,"
        f" {services} service spans == completed;"
        f" {totals['completed']}+{totals['dropped']}+{totals['shed']}"
        f" == {totals['requests']} requests;"
        f" {len(counters)} counter tracks reconciled;"
        f" {len(flow_starts)} flow arrow(s) paired and anchored"
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())
