#!/usr/bin/env python3
"""Bench regression gate for CI.

Usage: check_bench.py MEASURED.json BASELINE.json MAX_RATIO

Compares mean_ns per bench name against the checked-in baseline and fails
(exit 1) when any measured mean exceeds baseline * MAX_RATIO. Benches
missing from the baseline are reported but do not fail the run (new
benches land with a follow-up baseline update). The baseline values start
deliberately generous — CI machines vary — and should be ratcheted down
as real CI numbers accumulate; the script prints the measured file as a
ready-to-commit baseline snippet to make that easy.
"""

import json
import sys


def main() -> int:
    if len(sys.argv) != 4:
        print(__doc__)
        return 2
    measured_path, baseline_path, max_ratio = sys.argv[1], sys.argv[2], float(sys.argv[3])
    with open(measured_path) as f:
        measured = {e["name"]: e for e in json.load(f)}
    with open(baseline_path) as f:
        baseline = {e["name"]: e for e in json.load(f)}

    regressions = []
    print(f"{'bench':<48} {'measured_ms':>12} {'baseline_ms':>12} {'ratio':>7}")
    for name in sorted(measured):
        m = measured[name]["mean_ns"]
        b = baseline.get(name, {}).get("mean_ns")
        if b is None:
            print(f"{name:<48} {m / 1e6:>12.3f} {'(new)':>12} {'-':>7}")
            continue
        ratio = m / b if b > 0 else float("inf")
        flag = " REGRESSION" if ratio > max_ratio else ""
        print(f"{name:<48} {m / 1e6:>12.3f} {b / 1e6:>12.3f} {ratio:>7.2f}{flag}")
        if ratio > max_ratio:
            regressions.append((name, ratio))

    missing = sorted(set(baseline) - set(measured))
    for name in missing:
        print(f"{name:<48} {'(not measured this run)':>12}")

    print("\nmeasured snapshot (commit as the new baseline to ratchet):")
    snapshot = sorted(measured.values(), key=lambda e: e["name"])
    print(json.dumps(snapshot, indent=2))

    if regressions:
        worst = max(r for _, r in regressions)
        print(
            f"\nFAIL: {len(regressions)} bench(es) regressed more than "
            f"{(max_ratio - 1) * 100:.0f}% vs baseline (worst ratio {worst:.2f})"
        )
        return 1
    print(f"\nOK: no bench regressed more than {(max_ratio - 1) * 100:.0f}% vs baseline")
    return 0


if __name__ == "__main__":
    sys.exit(main())
