#!/usr/bin/env python3
"""Bench regression gate for CI.

Usage: check_bench.py MEASURED.json BASELINE.json THRESHOLD [THRESHOLD...]

Each THRESHOLD is either a bare ratio (gates mean_ns only — backwards
compatible) or metric=ratio (e.g. mean_ns=1.25 p99=1.60), so tail latency
is gated alongside the mean with its own, typically looser, budget.

For every gated metric, a bench fails when measured > baseline * ratio for
its name. Benches missing from the baseline are reported but do not fail
the run (new benches land with a follow-up baseline update); a metric
missing from a baseline entry is skipped for that bench. The baseline
values start deliberately generous — CI machines vary — and should be
ratcheted down as real CI numbers accumulate; the script prints the
measured file as a ready-to-commit baseline snippet to make that easy.
"""

import json
import sys


def parse_thresholds(args):
    thresholds = {}
    for arg in args:
        if "=" in arg:
            metric, ratio = arg.split("=", 1)
            thresholds[metric] = float(ratio)
        else:
            thresholds["mean_ns"] = float(arg)
    return thresholds


def load_entries(path, role):
    """Load a bench JSON file, failing the gate (exit 2) on a missing or
    malformed file instead of silently passing a broken baseline."""
    try:
        with open(path) as f:
            data = json.load(f)
    except OSError as e:
        print(f"ERROR: cannot read {role} file {path}: {e}")
        sys.exit(2)
    except json.JSONDecodeError as e:
        print(f"ERROR: {role} file {path} is not valid JSON: {e}")
        sys.exit(2)
    # Two accepted shapes: the legacy bare list, and the wrapped object
    # {"commit": ..., "date": ..., "entries": [...]} the harness writes.
    if isinstance(data, dict) and isinstance(data.get("entries"), list):
        data = data["entries"]
    if not isinstance(data, list) or not all(
        isinstance(e, dict) and isinstance(e.get("name"), str) for e in data
    ):
        print(
            f"ERROR: {role} file {path} must be a JSON list of objects with"
            " 'name' (bare or under an 'entries' key)"
        )
        sys.exit(2)
    return {e["name"]: e for e in data}


def main() -> int:
    if len(sys.argv) < 4:
        print(__doc__)
        return 2
    measured_path, baseline_path = sys.argv[1], sys.argv[2]
    thresholds = parse_thresholds(sys.argv[3:])
    measured = load_entries(measured_path, "measured")
    baseline = load_entries(baseline_path, "baseline")

    regressions = []
    worst = {}
    print(
        f"{'bench':<48} {'metric':>8} {'measured_ms':>12} {'baseline_ms':>12} {'ratio':>7}"
    )
    for name in sorted(measured):
        base_entry = baseline.get(name)
        if base_entry is None:
            m = measured[name].get("mean_ns", 0.0)
            print(f"{name:<48} {'mean_ns':>8} {m / 1e6:>12.3f} {'(new)':>12} {'-':>7}")
            continue
        for metric in sorted(thresholds):
            max_ratio = thresholds[metric]
            m = measured[name].get(metric)
            b = base_entry.get(metric)
            if m is None or b is None:
                continue
            ratio = m / b if b > 0 else float("inf")
            flag = " REGRESSION" if ratio > max_ratio else ""
            print(
                f"{name:<48} {metric:>8} {m / 1e6:>12.3f} {b / 1e6:>12.3f} {ratio:>7.2f}{flag}"
            )
            if ratio > max_ratio:
                regressions.append((name, metric, ratio, max_ratio))
            if metric not in worst or ratio > worst[metric][1]:
                worst[metric] = (name, ratio)

    missing = sorted(set(baseline) - set(measured))
    for name in missing:
        print(f"{name:<48} {'(not measured this run)':>12}")

    # Per-metric summary, printed on pass as well as fail, so green runs
    # still show how much headroom each budget has left.
    print("\nper-metric deltas vs baseline:")
    for metric in sorted(thresholds):
        if metric in worst:
            name, ratio = worst[metric]
            print(
                f"  {metric}: worst {ratio:.2f}x of budget"
                f" {thresholds[metric]:.2f}x ({name})"
            )
        else:
            print(f"  {metric}: no comparable benches")

    print("\nmeasured snapshot (commit as the new baseline to ratchet):")
    snapshot = sorted(measured.values(), key=lambda e: e["name"])
    print(json.dumps(snapshot, indent=2))

    if regressions:
        print(f"\nFAIL: {len(regressions)} bench metric(s) regressed:")
        for name, metric, ratio, max_ratio in regressions:
            print(
                f"  {name} {metric}: {ratio:.2f}x vs allowed {max_ratio:.2f}x"
            )
        return 1
    budgets = ", ".join(f"{m} <= {r:.2f}x" for m, r in sorted(thresholds.items()))
    print(f"\nOK: no bench regressed past its budget ({budgets})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
